//! Streaming event ingestion: [`EventSource`], a fallible chunked
//! iterator over time-sorted event batches.
//!
//! Every run path used to materialize the entire recording as a
//! `Vec<Event>` before the first event was processed, capping stream
//! length by host memory. Practical event pipelines (luvHarris; Sun et
//! al.'s memory-efficient DVS corner detection) must instead consume
//! unbounded live streams with bounded state. An [`EventSource`] yields
//! the stream in bounded chunks, so the coordinator's
//! [`run_stream`](crate::coordinator::Pipeline::run_stream) keeps peak
//! event-buffer memory O(chunk) regardless of recording length.
//!
//! Implementations:
//! * [`SliceSource`] — an in-memory slice, chunked (also the adapter that
//!   keeps the load-all [`run`](crate::coordinator::Pipeline::run) API).
//! * [`codec::BinaryStreamSource`](super::codec::BinaryStreamSource) —
//!   incremental binary-container decoding, no whole-file preallocation.
//! * [`codec::TextStreamSource`](super::codec::TextStreamSource) —
//!   line-streaming of the Mueggler `t x y p` text format.
//! * [`SceneSource`](crate::datasets::synthetic::SceneSource) — the
//!   synthetic scene generator, stepped on demand.
//!
//! [`open`] sniffs a file's container format and returns the right
//! decoder behind a `Box<dyn EventSource + Send>`.

use std::fs::File;
use std::io::{Read, Seek};
use std::path::Path;

use anyhow::{Context, Result};

use super::codec::{BinaryStreamSource, MAGIC, TextStreamSource};
use super::Event;

/// Default events per chunk: large enough to amortize per-chunk work,
/// small enough that a chunk buffer stays ~1 MiB.
pub const DEFAULT_CHUNK_EVENTS: usize = 65_536;

/// A fallible chunked iterator over a time-sorted event stream.
///
/// Contract: `next_chunk` appends up to one chunk of events (in stream
/// order, timestamps non-decreasing across calls) to `out` and returns
/// how many it appended; `Ok(0)` means the stream is exhausted. Errors
/// are sticky — callers should not retry a failed source.
pub trait EventSource {
    /// Append the next chunk of events to `out`; `Ok(0)` = end of stream.
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize>;

    /// Events remaining, when the source knows (slices, scenes); `None`
    /// for open-ended streams.
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

impl<S: EventSource + ?Sized> EventSource for Box<S> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        (**self).next_chunk(out)
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

impl<S: EventSource + ?Sized> EventSource for &mut S {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        (**self).next_chunk(out)
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

/// An in-memory slice served in fixed-size chunks.
#[derive(Debug)]
pub struct SliceSource<'a> {
    events: &'a [Event],
    pos: usize,
    chunk_events: usize,
}

impl<'a> SliceSource<'a> {
    /// Chunked view over a slice (`chunk_events` per `next_chunk` call).
    pub fn new(events: &'a [Event], chunk_events: usize) -> Self {
        Self { events, pos: 0, chunk_events: chunk_events.max(1) }
    }
}

impl EventSource for SliceSource<'_> {
    fn next_chunk(&mut self, out: &mut Vec<Event>) -> Result<usize> {
        let take = (self.events.len() - self.pos).min(self.chunk_events);
        out.extend_from_slice(&self.events[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.events.len() - self.pos)
    }
}

/// Open an event file as a streaming source, sniffing the container
/// format: the binary magic selects the binary decoder, anything else is
/// treated as `t x y p` text.
pub fn open(path: &Path, chunk_events: usize) -> Result<Box<dyn EventSource + Send>> {
    // probe and decode through one handle (rewound in between), so the
    // sniffed format always matches the file actually decoded
    let mut file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut probe = Vec::with_capacity(MAGIC.len());
    (&mut file).take(MAGIC.len() as u64).read_to_end(&mut probe)?;
    file.rewind()?;
    if probe == MAGIC {
        Ok(Box::new(BinaryStreamSource::new(file, chunk_events)?))
    } else {
        Ok(Box::new(TextStreamSource::new(file, chunk_events)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<Event> {
        (0..n).map(|i| Event::on((i % 50) as u16, (i % 40) as u16, i as u64 * 10)).collect()
    }

    fn drain(src: &mut impl EventSource) -> Vec<Event> {
        let mut out = Vec::new();
        while src.next_chunk(&mut out).unwrap() > 0 {}
        out
    }

    #[test]
    fn slice_source_chunks_cover_slice() {
        let evs = ramp(1000);
        for chunk in [1usize, 7, 256, 1000, 5000] {
            let mut src = SliceSource::new(&evs, chunk);
            assert_eq!(src.size_hint(), Some(1000));
            assert_eq!(drain(&mut src), evs, "chunk {chunk}");
            assert_eq!(src.size_hint(), Some(0));
        }
    }

    #[test]
    fn oversized_chunk_is_one_chunk() {
        let evs = ramp(123);
        let mut src = SliceSource::new(&evs, usize::MAX);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 123);
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert_eq!(out, evs);
    }

    #[test]
    fn empty_slice_terminates_immediately() {
        let mut src = SliceSource::new(&[], 64);
        let mut out = Vec::new();
        assert_eq!(src.next_chunk(&mut out).unwrap(), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn open_sniffs_binary_and_text() {
        let evs = ramp(500);
        let dir = std::env::temp_dir().join("nmc_tos_source_open");
        std::fs::create_dir_all(&dir).unwrap();

        let bin = dir.join("events.bin");
        let mut buf = Vec::new();
        crate::events::codec::write_binary(&mut buf, &evs).unwrap();
        std::fs::write(&bin, &buf).unwrap();
        let mut src = open(&bin, 64).unwrap();
        assert_eq!(drain(&mut src), evs);

        let txt = dir.join("events.txt");
        let mut buf = Vec::new();
        crate::events::codec::write_text(&mut buf, &evs).unwrap();
        std::fs::write(&txt, &buf).unwrap();
        let mut src = open(&txt, 64).unwrap();
        assert_eq!(drain(&mut src), evs);
    }

    #[test]
    fn boxed_and_borrowed_sources_dispatch() {
        let evs = ramp(32);
        let mut inner = SliceSource::new(&evs, 8);
        let mut by_ref: &mut SliceSource = &mut inner;
        assert_eq!(drain(&mut by_ref), evs);

        let mut boxed: Box<dyn EventSource + '_> = Box::new(SliceSource::new(&evs, 8));
        assert_eq!(boxed.size_hint(), Some(32));
        assert_eq!(drain(&mut boxed), evs);
    }
}
