//! `nmc-tos` — leader binary: end-to-end runs plus one subcommand per
//! table/figure of the paper (see DESIGN.md experiment index).
//!
//! ```text
//! nmc-tos fig1b                      # throughput comparison (Fig. 1b)
//! nmc-tos fig8   [--dataset driving] # DVFS trace (Fig. 8)
//! nmc-tos table1                     # power w/ vs w/o DVFS (Table I)
//! nmc-tos fig9                       # latency/energy vs Vdd (Fig. 9)
//! nmc-tos fig10                      # breakdowns + power vs rate (Fig. 10)
//! nmc-tos ber    [--reads N]         # Monte-Carlo BER sweep (Sec. V-C)
//! nmc-tos fig11  [--events N]        # PR curves + AUC deltas (Fig. 11)
//! nmc-tos vdd-sweep [--smoke] [--events N] [--backends B,B] [--detector D]
//!                                    # end-to-end BER + PR-AUC vs Vdd with
//!                                    # seeded fault injection (fidelity
//!                                    # harness; byte-reproducible report)
//! nmc-tos dataset-eval [--manifest FILE] [--smoke] [--backends B,B]
//!                [--detectors D,D] [--radius R] [--events N]
//!                [--chunk-events N]  # PR-AUC on real recordings
//!                                    # (AEDAT4/EVT2/EVT3/bin/text, sniffed)
//!                                    # vs corner-label sidecars;
//!                                    # byte-reproducible report
//! nmc-tos run    [--events N] [--async]
//!                [--backend nmc|conventional|golden|sharded]
//!                [--detector harris|eharris|fast|arc] [--shards N]
//!                [--eharris-window N]
//!                [--input FILE] [--chunk-events N] [--no-record]
//!                                    # end-to-end demo on shapes_dof, or
//!                                    # stream a recording with bounded memory
//! nmc-tos serve  [--listen ADDR] [--max-streams N] [--sessions N]
//!                [--backend B] [--detector D] [--stats-interval N]
//!                [--degrade] [--degrade-lag S] [--degrade-fallback D]
//!                                    # multi-stream server over TCP;
//!                                    # v2+ sessions stream corners + stats;
//!                                    # --degrade sheds load (Vdd steps,
//!                                    # detector swap) instead of lagging
//! nmc-tos feed   --input FILE [--connect ADDR] [--res WxH]
//!                [--chunk-events N] [--stream-id N]
//!                [--print-corners] [--wire-version 1|2|3]
//!                                    # stream a recording to a server and
//!                                    # receive corners live (protocol v3)
//! nmc-tos lut                        # DVFS V/f lookup table
//! ```
//!
//! Every command prints the paper-comparable rows and (with `--json PATH`)
//! dumps machine-readable results.

// the binary has no business doing unsafe work — all SIMD lives behind
// the library's `tos::kernel` / `stcf` allowlist
#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use nmc_tos::conventional::ConventionalModel;
use nmc_tos::coordinator::{Corner, CornerSink, LiveStats, Pipeline, PipelineConfig};
use nmc_tos::datasets::{profiles::RateProfile, synthetic::SceneConfig, DatasetKind};
use nmc_tos::detectors::{self, eharris::EHarris, EventScorer};
use nmc_tos::dvfs::DvfsConfig;
use nmc_tos::eval::{PrCurve, ScoredSink};
use nmc_tos::events::Resolution;
use nmc_tos::nmc::{calib, energy::EnergyModel, montecarlo, timing::TimingModel};
use nmc_tos::power;
use nmc_tos::util::json::Json;

/// Minimal flag parser: positional command + `--key value` / `--flag`.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let mut key: Option<String> = None;
        for a in it {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(k) = key.take() {
                    flags.insert(k, "true".into());
                }
                key = Some(stripped.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, a);
            }
        }
        if let Some(k) = key.take() {
            flags.insert(k, "true".into());
        }
        Args { cmd, flags }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn num(&self, k: &str, default: f64) -> f64 {
        self.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, k: &str) -> bool {
        self.get(k).is_some()
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let json_out = args.get("json").map(|s| s.to_string());
    let result = match args.cmd.as_str() {
        "fig1b" => cmd_fig1b(),
        "fig8" => cmd_fig8(&args),
        "table1" => cmd_table1(),
        "fig9" => cmd_fig9(),
        "fig10" => cmd_fig10(),
        "ber" => cmd_ber(&args),
        "fig11" => cmd_fig11(&args),
        "vdd-sweep" => cmd_vdd_sweep(&args),
        "dataset-eval" => cmd_dataset_eval(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "feed" => cmd_feed(&args),
        "lut" => cmd_lut(),
        "ablate" => cmd_ablate(&args),
        "waveform" => cmd_waveform(&args),
        "gen-data" => cmd_gen_data(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(Json::Null)
        }
        other => bail!("unknown command `{other}` — try `nmc-tos help`"),
    }?;
    if let Some(path) = json_out {
        std::fs::write(&path, result.render()).with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

const HELP: &str = "nmc-tos — NMC-TOS full-system reproduction
commands: fig1b fig8 table1 fig9 fig10 ber fig11 vdd-sweep dataset-eval run serve feed lut ablate waveform gen-data
common flags: --json PATH (dump machine-readable results)
run flags:    --backend nmc|conventional|golden|sharded  --detector harris|eharris|fast|arc
              --shards N  --events N  --async  --eharris-window N (binary-surface window, default 2000)
              --input FILE (stream a recording, bounded memory)
              --chunk-events N (default 65536)  --no-record (counters only)
vdd-sweep:    --smoke (small CI grid)  --events N (per scene)  --detector D
              --backends B,B (default nmc)  --seed N (fault-map seed)
              end-to-end BER + PR-AUC per voltage; same seeds = same bytes
dataset-eval: --manifest FILE (default rust/tests/fixtures/datasets/manifest.json)
              --smoke (CI grid: golden+nmc x harris+fast, capped events)
              --backends B,B  --detectors D,D  --radius R (label match px)
              --events N (cap per recording)  --chunk-events N (default 65536)
              PR-AUC on real recordings vs corner-label sidecars; no
              downloads — missing files name the manifest's url as a hint
serve flags:  --listen ADDR (default 127.0.0.1:7700)  --max-streams N (default 4)
              --sessions N (serve N connections then exit; default: run until killed)
              --backend B  --detector D  --shards N  --eharris-window N
              --stats-interval N (stream live stats to v2+ clients every N events)
              --degrade (adaptive degradation: shed Vdd steps, then swap to
              --degrade-fallback D (default fast) when realtime lag exceeds
              --degrade-lag S (default 0.25); recovery with hysteresis)
feed flags:   --input FILE (required)  --connect ADDR (default 127.0.0.1:7700)
              --res WxH|davis240|davis346|hd720|test64 (default davis240)
              --chunk-events N (default 16384)  --stream-id N
              --print-corners (print corners as they stream back)
              --wire-version 1|2|3 (default 3; 1 = summary-only legacy session)
see DESIGN.md for the experiment index";

// ---------------------------------------------------------------------------

/// Fig. 1(b): max throughput of eHarris / conventional luvHarris /
/// NMC-TOS, against the DAVIS240 bus bandwidth.
fn cmd_fig1b() -> Result<Json> {
    let eh = EHarris::new(Resolution::DAVIS240);
    let eharris = detectors::max_throughput_eps(eh.ops_per_event(), calib::CONV_CLOCK_NOM_HZ);
    let conv = ConventionalModel::at(1.2).max_event_rate();
    let nmc = TimingModel::at(1.2).max_event_rate();
    let bw = calib::DAVIS240_BANDWIDTH_EPS;

    println!("== Fig. 1(b): max supported event rate (Meps) ==");
    println!("{:<28}{:>12}", "method", "Meps");
    println!("{:<28}{:>12.2}", "eHarris (500 MHz digital)", eharris / 1e6);
    println!("{:<28}{:>12.2}", "luvHarris conventional TOS", conv / 1e6);
    println!("{:<28}{:>12.2}", "NMC-TOS @1.2 V (ours)", nmc / 1e6);
    println!("{:<28}{:>12.2}", "DAVIS240 bus bandwidth", bw / 1e6);
    println!(
        "-> only NMC-TOS exceeds the sensor bandwidth ({}x the conventional TOS)",
        (nmc / conv).round()
    );
    Ok(Json::obj(vec![
        ("eharris_meps", Json::Num(eharris / 1e6)),
        ("conventional_meps", Json::Num(conv / 1e6)),
        ("nmc_meps", Json::Num(nmc / 1e6)),
        ("davis240_bw_meps", Json::Num(bw / 1e6)),
    ]))
}

/// Fig. 8: DVFS trace over the driving dataset.
fn cmd_fig8(args: &Args) -> Result<Json> {
    let kind = match args.get("dataset").unwrap_or("driving") {
        "driving" => DatasetKind::Driving,
        "laser" => DatasetKind::Laser,
        "spinner" => DatasetKind::Spinner,
        "dynamic_dof" => DatasetKind::DynamicDof,
        "shapes_dof" => DatasetKind::ShapesDof,
        other => bail!("unknown dataset {other}"),
    };
    let profile = RateProfile::for_dataset(kind);
    let report = power::integrate(&profile, DvfsConfig::default(), 25);

    println!("== Fig. 8: DVFS trace on `{}` ==", report.dataset);
    println!("{:>8} {:>12} {:>8} {:>14}", "t (s)", "rate (Meps)", "Vdd", "capacity(Meps)");
    for &(t, rate, vdd, cap) in &report.trace {
        let bar_len = (rate / 64e6 * 40.0) as usize;
        println!(
            "{:>8.2} {:>12.2} {:>8.2} {:>14.1}  |{}",
            t,
            rate / 1e6,
            vdd,
            cap / 1e6,
            "#".repeat(bar_len)
        );
    }
    println!(
        "events {:.1}M  peak {:.1} Meps  switches {}  event loss: {}",
        report.events / 1e6,
        report.peak_rate / 1e6,
        report.switches,
        if report.no_event_loss { "none" } else { "YES" }
    );
    Ok(Json::obj(vec![
        ("dataset", Json::Str(report.dataset.into())),
        ("peak_meps", Json::Num(report.peak_rate / 1e6)),
        ("switches", Json::Num(report.switches as f64)),
        ("no_event_loss", Json::Bool(report.no_event_loss)),
        (
            "trace",
            Json::Arr(
                report
                    .trace
                    .iter()
                    .map(|&(t, r, v, c)| {
                        Json::Arr(vec![Json::Num(t), Json::Num(r), Json::Num(v), Json::Num(c)])
                    })
                    .collect(),
            ),
        ),
    ]))
}

/// Table I: power with vs without DVFS on all five datasets.
fn cmd_table1() -> Result<Json> {
    println!("== Table I: power improvement using DVFS ==");
    println!(
        "{:<14}{:>14}{:>12}{:>16}{:>17}{:>9}",
        "dataset", "max rate Meps", "events M", "P w/ DVFS mW", "P w/o DVFS mW", "saving"
    );
    let mut rows = Vec::new();
    for kind in DatasetKind::ALL {
        let p = RateProfile::for_dataset(kind);
        let r = power::integrate(&p, DvfsConfig::default(), 64);
        println!(
            "{:<14}{:>14.1}{:>12.1}{:>16.3}{:>17.3}{:>8.1}x",
            r.dataset,
            r.peak_rate / 1e6,
            r.events / 1e6,
            r.power_dvfs_mw,
            r.power_fixed_mw,
            r.power_fixed_mw / r.power_dvfs_mw
        );
        rows.push(Json::obj(vec![
            ("dataset", Json::Str(r.dataset.into())),
            ("peak_meps", Json::Num(r.peak_rate / 1e6)),
            ("events_m", Json::Num(r.events / 1e6)),
            ("power_dvfs_mw", Json::Num(r.power_dvfs_mw)),
            ("power_fixed_mw", Json::Num(r.power_fixed_mw)),
        ]));
    }
    println!("(paper: driving 0.44/1.24, laser 3.90/5.37, spinner 0.38/1.50,");
    println!("        dynamic_dof 0.02/0.13, shapes_dof 0.01/0.04 mW)");
    Ok(Json::Arr(rows))
}

/// Fig. 9: latency & energy vs voltage, plus the headline ratios.
fn cmd_fig9() -> Result<Json> {
    println!("== Fig. 9(a): 7x7 patch update latency & energy vs Vdd ==");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14}",
        "Vdd", "NMC lat (ns)", "NMC E (pJ)", "conv lat (ns)", "conv E (pJ)"
    );
    let mut rows = Vec::new();
    for mv in (600..=1200).step_by(100) {
        let v = mv as f64 / 1000.0;
        let t = TimingModel::at(v);
        let e = EnergyModel::at(v);
        let c = ConventionalModel::at(v);
        let nmc_lat = t.patch_latency_pipelined_ns(calib::PATCH);
        let conv_lat = c.event_latency_ns(49);
        println!(
            "{:>6.2} {:>14.1} {:>14.1} {:>14.1} {:>14.1}",
            v, nmc_lat, e.patch_pj, conv_lat, c.energy.patch_pj
        );
        rows.push(Json::obj(vec![
            ("vdd", Json::Num(v)),
            ("nmc_latency_ns", Json::Num(nmc_lat)),
            ("nmc_energy_pj", Json::Num(e.patch_pj)),
            ("conv_latency_ns", Json::Num(conv_lat)),
            ("conv_energy_pj", Json::Num(c.energy.patch_pj)),
        ]));
    }

    let conv = ConventionalModel::at(1.2).event_latency_ns(49);
    let t12 = TimingModel::at(1.2);
    let x_nopipe = conv / t12.patch_latency_unpipelined_ns(calib::PATCH);
    let x_pipe = conv / t12.patch_latency_pipelined_ns(calib::PATCH);
    println!("\n== Fig. 9(b): latency reduction @1.2 V ==");
    println!("conventional -> NMC          : {x_nopipe:.1}x   (paper: 13.0x)");
    println!("conventional -> NMC+pipeline : {x_pipe:.1}x   (paper: 24.7x)");

    let e_conv = ConventionalModel::at(1.2).energy.patch_pj;
    let e_nmc = EnergyModel::at(1.2).patch_pj;
    let e_dvfs = EnergyModel::at(0.6).patch_pj;
    println!("\n== Fig. 9(c): energy reduction ==");
    println!("conventional -> NMC @1.2 V   : {:.2}x   (paper: 1.2x)", e_conv / e_nmc);
    println!("conventional -> NMC+DVFS 0.6V: {:.1}x   (paper: 6.6x)", e_conv / e_dvfs);

    Ok(Json::obj(vec![
        ("sweep", Json::Arr(rows)),
        ("latency_reduction_nmc", Json::Num(x_nopipe)),
        ("latency_reduction_pipeline", Json::Num(x_pipe)),
        ("energy_reduction_nmc", Json::Num(e_conv / e_nmc)),
        ("energy_reduction_dvfs", Json::Num(e_conv / e_dvfs)),
    ]))
}

/// Fig. 10: breakdowns, power vs rate, latency/throughput vs Vdd.
fn cmd_fig10() -> Result<Json> {
    println!("== Fig. 10(a): energy breakdown @1.2 V ==");
    let e = EnergyModel::at(1.2);
    let parts = e.breakdown_pj();
    let total: f64 = parts.iter().sum();
    let mut breakdown = Vec::new();
    for (label, pj) in calib::ENERGY_SHARE_LABELS.iter().zip(parts) {
        println!("{:<12} {:>8.1} pJ  {:>5.1} %", label, pj, pj / total * 100.0);
        breakdown.push(Json::obj(vec![
            ("module", Json::Str((*label).into())),
            ("energy_pj", Json::Num(pj)),
        ]));
    }

    println!("\n== Fig. 10(b): power vs event rate (mW) ==");
    println!("{:>12} {:>14} {:>12} {:>12}", "rate Meps", "conventional", "NMC", "NMC+DVFS");
    let rates: Vec<f64> = (1..=13).map(|i| i as f64 * 5e6).collect();
    let mut pvr = Vec::new();
    for (r, conv, fixed, dvfs) in power::power_vs_rate(&rates) {
        println!("{:>12.0} {:>14.2} {:>12.2} {:>12.2}", r / 1e6, conv, fixed, dvfs);
        pvr.push(Json::Arr(vec![
            Json::Num(r / 1e6),
            Json::Num(conv),
            Json::Num(fixed),
            Json::Num(dvfs),
        ]));
    }

    println!("\n== Fig. 10(c): phase delay breakdown @0.6 V ==");
    let t06 = TimingModel::at(0.6);
    let mut phases = Vec::new();
    let row: f64 = nmc_tos::nmc::timing::Phase::ALL.iter().map(|&p| t06.phase_ns(p)).sum();
    for p in nmc_tos::nmc::timing::Phase::ALL {
        println!(
            "{:<5} {:>8.1} ns  {:>5.1} %",
            p.label(),
            t06.phase_ns(p),
            t06.phase_ns(p) / row * 100.0
        );
        phases.push(Json::obj(vec![
            ("phase", Json::Str(p.label().into())),
            ("delay_ns", Json::Num(t06.phase_ns(p))),
        ]));
    }

    println!("\n== Fig. 10(d): per-event latency & max throughput vs Vdd ==");
    println!("{:>6} {:>14} {:>16} {:>18}", "Vdd", "NMC lat (ns)", "NMC+pipe (Meps)", "conv (Meps)");
    let mut sweep = Vec::new();
    for mv in (600..=1200).step_by(50) {
        let v = mv as f64 / 1000.0;
        let t = TimingModel::at(v);
        let conv = ConventionalModel::at(v);
        println!(
            "{:>6.2} {:>14.1} {:>16.1} {:>18.2}",
            v,
            t.patch_latency_pipelined_ns(calib::PATCH),
            t.max_event_rate() / 1e6,
            conv.max_event_rate() / 1e6
        );
        sweep.push(Json::Arr(vec![
            Json::Num(v),
            Json::Num(t.patch_latency_pipelined_ns(calib::PATCH)),
            Json::Num(t.max_event_rate() / 1e6),
            Json::Num(conv.max_event_rate() / 1e6),
        ]));
    }
    Ok(Json::obj(vec![
        ("breakdown", Json::Arr(breakdown)),
        ("power_vs_rate", Json::Arr(pvr)),
        ("phases", Json::Arr(phases)),
        ("sweep", Json::Arr(sweep)),
    ]))
}

/// Monte-Carlo BER sweep (Sec. V-C).
fn cmd_ber(args: &Args) -> Result<Json> {
    let reads = args.num("reads", 200_000.0) as u64;
    let voltages = [0.58, 0.59, 0.60, 0.61, 0.62, 0.63, 0.65, 0.70];
    println!("== Monte-Carlo BER vs Vdd ({reads} reads/point) ==");
    println!("{:>6} {:>12} {:>12} {:>12}", "Vdd", "errors", "BER", "model BER");
    let pts = montecarlo::ber_sweep(&voltages, reads, 0xBE12);
    let mut rows = Vec::new();
    for p in &pts {
        println!("{:>6.2} {:>12} {:>12.5} {:>12.2e}", p.vdd, p.errors, p.ber, p.model_ber);
        rows.push(Json::obj(vec![
            ("vdd", Json::Num(p.vdd)),
            ("ber", Json::Num(p.ber)),
            ("model_ber", Json::Num(p.model_ber)),
        ]));
    }
    println!("(paper: 2.5% @0.60 V, 0.2% @0.61 V, zero at/above 0.62 V)");
    Ok(Json::Arr(rows))
}

/// Fig. 11: PR curves + AUC deltas under BER for both scene datasets.
fn cmd_fig11(args: &Args) -> Result<Json> {
    let n_events = args.num("events", 400_000.0) as usize;
    let radius = args.num("radius", 3.5) as f32;
    let render = args.flag("render");
    let mut out = Vec::new();
    for (name, cfg_fn) in [
        ("shapes_dof", SceneConfig::shapes_dof as fn() -> SceneConfig),
        ("dynamic_dof", SceneConfig::dynamic_dof as fn() -> SceneConfig),
    ] {
        println!("== Fig. 11: {name} ({n_events} events) ==");
        let mut scene = cfg_fn().build(42);
        let (events, gt) = scene.generate_with_gt(n_events);

        let mut aucs = Vec::new();
        for (label, vdd, inject) in
            [("error-free @1.2 V", 1.2, false), ("BER 0.2% @0.61 V", 0.61, true), ("BER 2.5% @0.6 V", 0.6, true)]
        {
            let mut cfg = PipelineConfig::davis240();
            cfg.dvfs = None; // pin the voltage for a controlled BER level
            cfg.fixed_vdd = vdd;
            cfg.inject_errors = inject;
            cfg.seed = 7;
            // AUC through the streaming evaluation path: a ScoredSink
            // labels events as they flow, so no per-event report vectors
            cfg.record_per_event = false;
            let mut pipe = Pipeline::new(cfg)?;
            let mut sink = ScoredSink::new(&gt, radius);
            let report = pipe.run_with(&events, &mut sink)?;
            let curve = sink.curve(101);
            let auc = curve.auc();
            println!(
                "{:<20} AUC {:.3}  (signal events {}, LUT refreshes {}, flipped bits {})",
                label, auc, report.events_signal, report.lut_refreshes, report.backend.flipped_bits
            );
            if render && vdd == 1.2 {
                render_ascii(&report.final_tos, 240, 16);
            }
            aucs.push((label, auc));
        }
        let base = aucs[0].1;
        for (label, auc) in &aucs[1..] {
            println!("  dAUC {label}: {:+.3}", auc - base);
        }
        println!("(paper: dAUC -0.027 shapes_dof, -0.015 dynamic_dof at BER 2.5%)\n");
        out.push(Json::obj(vec![
            ("dataset", Json::Str(name.into())),
            (
                "aucs",
                Json::Arr(
                    aucs.iter()
                        .map(|(l, a)| {
                            Json::obj(vec![("config", Json::Str((*l).into())), ("auc", Json::Num(*a))])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    Ok(Json::Arr(out))
}

/// End-to-end voltage-fault fidelity sweep: the seeded fault injector
/// live in the TOS hot path, detection quality measured per voltage.
/// Reproduces the paper's curve shape — zero observed errors at and
/// above 0.62 V, small nonzero BER at 0.61/0.60 V, bounded AUC loss —
/// and the report renders byte-identically for identical seeds.
fn cmd_vdd_sweep(args: &Args) -> Result<Json> {
    use nmc_tos::eval::{run_vdd_sweep, SweepConfig};
    let mut cfg = if args.flag("smoke") { SweepConfig::smoke() } else { SweepConfig::paper() };
    cfg.events = args.num("events", cfg.events as f64) as usize;
    cfg.fault_seed = args.num("seed", cfg.fault_seed as f64) as u64;
    if let Some(d) = args.get("detector") {
        cfg.detector = d.parse()?;
    }
    if let Some(list) = args.get("backends") {
        cfg.backends =
            list.split(',').map(|b| b.parse()).collect::<Result<Vec<_>>>()?;
    }
    println!(
        "== vdd-sweep: {} scenarios x {} backends, {} events/scene (seed {}) ==",
        cfg.scenarios.len(),
        cfg.backends.len(),
        cfg.events,
        cfg.fault_seed
    );
    let rep = run_vdd_sweep(&cfg)?;
    println!(
        "{:<34} {:>12} {:>6} {:>10} {:>10} {:>9} {:>7} {:>8}",
        "scenario", "backend", "Vdd", "model BER", "read err", "faulty", "AUC", "dAUC"
    );
    for p in &rep.points {
        println!(
            "{:<34} {:>12} {:>6.2} {:>10.2e} {:>10.2e} {:>9} {:>7.3} {:>+8.3}",
            p.scenario,
            p.backend,
            p.vdd,
            p.model_ber,
            p.read_error_rate,
            p.faulty_cells,
            p.auc,
            p.auc_delta
        );
    }
    println!("(paper: BER zero at/above 0.62 V, 0.2% @0.61 V, 2.5% @0.60 V; dAUC -0.027)");
    Ok(rep.to_json())
}

/// Public-dataset AUC harness: stream real recordings (format sniffed —
/// AEDAT4, Prophesee EVT2/EVT3, binary or text container) through the
/// pipeline and score every detector x backend x dataset cell against
/// the corner-label sidecars a manifest declares. The default manifest
/// points at the checked-in golden fixtures, so the command runs out of
/// the box; point `--manifest` at a real dataset directory for the full
/// evaluation. Reports render byte-identically across repeat runs.
fn cmd_dataset_eval(args: &Args) -> Result<Json> {
    use nmc_tos::eval::{run_dataset_eval, DatasetEvalConfig};
    let manifest = args
        .get("manifest")
        .unwrap_or("rust/tests/fixtures/datasets/manifest.json")
        .to_string();
    let mut cfg = if args.flag("smoke") {
        DatasetEvalConfig::smoke(&manifest)
    } else {
        DatasetEvalConfig::new(&manifest)
    };
    if let Some(list) = args.get("backends") {
        cfg.backends = list.split(',').map(|b| b.parse()).collect::<Result<Vec<_>>>()?;
    }
    if let Some(list) = args.get("detectors") {
        cfg.detectors = list.split(',').map(|d| d.parse()).collect::<Result<Vec<_>>>()?;
    }
    cfg.radius_px = args.num("radius", cfg.radius_px as f64) as f32;
    cfg.chunk_events = args.num("chunk-events", cfg.chunk_events as f64) as usize;
    if let Some(n) = args.get("events") {
        cfg.max_events = Some(n.parse::<usize>().context("bad --events value")?);
    }
    println!(
        "== dataset-eval: {} x {} backends x {} detectors (radius {} px) ==",
        manifest,
        cfg.backends.len(),
        cfg.detectors.len(),
        cfg.radius_px
    );
    let rep = run_dataset_eval(&cfg)?;
    println!(
        "{:<18} {:>12} {:>14} {:>10} {:>10} {:>9} {:>7} {:>8}",
        "dataset", "backend", "detector", "events", "signal", "positives", "AUC", "best F1"
    );
    for p in &rep.points {
        println!(
            "{:<18} {:>12} {:>14} {:>10} {:>10} {:>9} {:>7.3} {:>8.3}",
            p.dataset,
            p.backend,
            p.detector,
            p.events_in,
            p.events_signal,
            p.positives,
            p.auc,
            p.best_f1
        );
    }
    Ok(rep.to_json())
}

/// ASCII-render a TOS snapshot (Fig. 11(b) stand-in for headless runs).
fn render_ascii(tos: &[u8], width: usize, rows_shown: usize) {
    let height = tos.len() / width;
    let step_y = (height / rows_shown).max(1);
    let step_x = (width / 80).max(1);
    let ramp = b" .:-=+*#%@";
    for y in (0..height).step_by(step_y) {
        let mut line = String::new();
        for x in (0..width).step_by(step_x) {
            let v = tos[y * width + x] as usize;
            line.push(ramp[v * (ramp.len() - 1) / 255] as char);
        }
        println!("{line}");
    }
}

/// End-to-end demo: full pipeline (STCF + TOS backend + DVFS + detector),
/// optionally with the async LUT worker. The backend x detector
/// combination is chosen with `--backend`/`--detector`; SAE detectors
/// skip the PJRT engine entirely. Default input is the shapes_dof scene;
/// `--input FILE` instead streams a recording (binary container or
/// `t x y p` text, sniffed) from disk in `--chunk-events` chunks with
/// bounded memory — add `--no-record` for unbounded recordings so the
/// report keeps counters instead of per-event vectors.
fn cmd_run(args: &Args) -> Result<Json> {
    let n_events = args.num("events", 200_000.0) as usize;
    let mut cfg = PipelineConfig::davis240();
    cfg.async_refresh = args.flag("async");
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(d) = args.get("detector") {
        cfg.detector = d.parse()?;
    }
    cfg.shards = args.num("shards", cfg.shards as f64) as usize;
    cfg.eharris_window = args.num("eharris-window", cfg.eharris_window as f64) as usize;
    if let Some(input) = args.get("input") {
        return cmd_run_stream(args, cfg, input);
    }
    let mut scene = SceneConfig::shapes_dof().build(args.num("seed", 42.0) as u64);
    let (events, gt) = scene.generate_with_gt(n_events);
    let mut pipe = Pipeline::from_config(cfg)?;
    let report = pipe.run(&events)?;
    let scored = report.scored_events(&gt, 3.5);
    let auc = PrCurve::from_scores(&scored, 101).auc();
    println!("== end-to-end run (shapes_dof scene) ==");
    println!("backend / detector   : {} / {}", report.backend_name, report.detector_name);
    println!("events in            : {}", report.events_in);
    println!("signal after STCF    : {}", report.events_signal);
    println!("corners tagged       : {}", report.corners.len());
    println!("LUT refreshes        : {}", report.lut_refreshes);
    println!("DVFS switches        : {}", report.dvfs_switches);
    println!("PR-AUC vs ground truth: {auc:.3}");
    println!("simulated busy       : {:.3} ms", report.backend.busy_ns / 1e6);
    println!("simulated energy     : {:.3} µJ", report.backend.energy_pj / 1e6);
    println!("TOS kernel path      : {}", report.backend.kernel);
    println!("wall time            : {:.2} s ({:.0} keps)",
        report.wall_s, report.events_in as f64 / report.wall_s / 1e3);
    Ok(Json::obj(vec![
        ("backend", Json::Str(report.backend_name.into())),
        ("detector", Json::Str(report.detector_name.into())),
        ("events_in", Json::Num(report.events_in as f64)),
        ("events_signal", Json::Num(report.events_signal as f64)),
        ("corners", Json::Num(report.corners.len() as f64)),
        ("lut_refreshes", Json::Num(report.lut_refreshes as f64)),
        ("auc", Json::Num(auc)),
        ("busy_ns", Json::Num(report.backend.busy_ns)),
        ("energy_pj", Json::Num(report.backend.energy_pj)),
        ("kernel", Json::Str(report.backend.kernel.as_str().into())),
        ("wall_s", Json::Num(report.wall_s)),
    ]))
}

/// `run --input FILE`: stream a recording from disk with bounded memory
/// (no ground truth, so no AUC — counters and simulated cost instead).
fn cmd_run_stream(args: &Args, mut cfg: PipelineConfig, input: &str) -> Result<Json> {
    let default_chunk = nmc_tos::events::source::DEFAULT_CHUNK_EVENTS as f64;
    let chunk = args.num("chunk-events", default_chunk) as usize;
    cfg.record_per_event = !args.flag("no-record");
    let mut source = nmc_tos::events::source::open(std::path::Path::new(input), chunk)?;
    let mut pipe = Pipeline::from_config(cfg)?;
    let report = pipe.run_stream(&mut source)?;
    println!("== streamed run ({input}, chunks of {chunk}) ==");
    println!("backend / detector   : {} / {}", report.backend_name, report.detector_name);
    println!("events in            : {}", report.events_in);
    println!("signal after STCF    : {}", report.events_signal);
    println!("corners tagged       : {}", report.corners_total);
    println!("LUT refreshes        : {}", report.lut_refreshes);
    println!("DVFS switches        : {}", report.dvfs_switches);
    println!("simulated busy       : {:.3} ms", report.backend.busy_ns / 1e6);
    println!("simulated energy     : {:.3} µJ", report.backend.energy_pj / 1e6);
    println!("TOS kernel path      : {}", report.backend.kernel);
    println!(
        "wall time            : {:.2} s ({:.0} keps)",
        report.wall_s,
        report.events_in as f64 / report.wall_s.max(1e-9) / 1e3
    );
    Ok(Json::obj(vec![
        ("input", Json::Str(input.into())),
        ("chunk_events", Json::Num(chunk as f64)),
        ("backend", Json::Str(report.backend_name.into())),
        ("detector", Json::Str(report.detector_name.into())),
        ("events_in", Json::Num(report.events_in as f64)),
        ("events_signal", Json::Num(report.events_signal as f64)),
        ("corners", Json::Num(report.corners_total as f64)),
        ("lut_refreshes", Json::Num(report.lut_refreshes as f64)),
        ("dvfs_switches", Json::Num(report.dvfs_switches as f64)),
        ("busy_ns", Json::Num(report.backend.busy_ns)),
        ("energy_pj", Json::Num(report.backend.energy_pj)),
        ("kernel", Json::Str(report.backend.kernel.as_str().into())),
        ("wall_s", Json::Num(report.wall_s)),
    ]))
}

/// Parse `--res`: a named sensor or `WIDTHxHEIGHT`.
fn parse_res(s: &str) -> Result<Resolution> {
    Ok(match s {
        "davis240" => Resolution::DAVIS240,
        "davis346" => Resolution::DAVIS346,
        "hd720" => Resolution::HD720,
        "test64" => Resolution::TEST64,
        other => {
            let (w, h) = other
                .split_once('x')
                .context("--res takes WxH or davis240|davis346|hd720|test64")?;
            let w: u16 = w.parse().context("bad --res width")?;
            let h: u16 = h.parse().context("bad --res height")?;
            anyhow::ensure!(w > 0 && h > 0, "--res {other} is degenerate");
            Resolution::new(w, h)
        }
    })
}

/// `serve`: accept event streams over TCP and drive each through the
/// pipeline on a worker pool — one `TosBackend` + detector per stream,
/// Harris engines shared through a per-resolution pool. Each session's
/// resolution and protocol version come from the client handshake;
/// backend/detector are server policy. Protocol-v2 sessions stream
/// corner batches back as they are tagged, plus live per-session stats
/// every `--stats-interval N` events. `--sessions N` serves N
/// connections then prints the aggregate stats (scripted runs); without
/// it the server runs until killed.
fn cmd_serve(args: &Args) -> Result<Json> {
    use nmc_tos::serve::{ServeConfig, StreamServer};
    let listen = args.get("listen").unwrap_or("127.0.0.1:7700").to_string();
    let mut cfg = PipelineConfig::davis240();
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(d) = args.get("detector") {
        cfg.detector = d.parse()?;
    }
    cfg.shards = args.num("shards", cfg.shards as f64) as usize;
    cfg.eharris_window = args.num("eharris-window", cfg.eharris_window as f64) as usize;
    // counters only: streams may be unbounded, and the CLI server has no
    // consumer for per-event vectors (library embedders that want full
    // reports use ServeConfig::keep_reports + StreamServer::take_reports;
    // wire clients get per-corner results streamed over protocol v2)
    cfg.record_per_event = false;
    if let Some(v) = args.get("stats-interval") {
        // live per-session stats to v2 clients every N input events
        cfg.stats_interval_events = Some(v.parse::<u64>().context("bad --stats-interval value")?);
    }
    let backend = cfg.backend;
    let detector = cfg.detector;
    let mut serve_cfg = ServeConfig::new(cfg);
    serve_cfg.max_streams = args.num("max-streams", 4.0) as usize;
    if args.flag("degrade") {
        // adaptive degradation: under realtime lag, step the supply
        // voltage down (trading read fidelity) and finally swap to the
        // cheaper fallback detector instead of falling behind
        let defaults = nmc_tos::serve::DegradeConfig::default();
        let fallback = match args.get("degrade-fallback") {
            Some(d) => d.parse()?,
            None => defaults.fallback,
        };
        serve_cfg.degrade = Some(nmc_tos::serve::DegradeConfig {
            lag_shed_s: args.num("degrade-lag", defaults.lag_shed_s),
            fallback,
            ..defaults
        });
    }
    let sessions = match args.get("sessions") {
        Some(s) => Some(s.parse::<usize>().context("bad --sessions value")?),
        None => None,
    };

    let server = StreamServer::new(serve_cfg)?;
    let listener = std::net::TcpListener::bind(&listen)
        .with_context(|| format!("binding {listen}"))?;
    println!(
        "serving on {listen} — {} workers, backend {} / detector {}{}",
        args.num("max-streams", 4.0) as usize,
        backend.label(),
        detector.label(),
        match sessions {
            Some(n) => format!(", exiting after {n} sessions"),
            None => " (^C to stop)".into(),
        }
    );
    server.serve(&listener, sessions)?;
    let stats = server.shutdown();
    println!("== server stats ==");
    println!("sessions completed   : {}", stats.sessions_completed);
    println!("sessions failed      : {}", stats.sessions_failed);
    println!("events ingested      : {}", stats.events_in);
    println!("signal after STCF    : {}", stats.events_signal);
    println!("corners tagged       : {}", stats.corners_total);
    println!("peak concurrency     : {}", stats.peak_concurrent);
    println!("mean ingest rate     : {:.0} keps", stats.events_per_sec() / 1e3);
    println!("worst realtime lag   : {:+.3} s", stats.worst_lag_s);
    println!("v2+ sessions         : {}", stats.sessions_v2);
    println!("corners streamed     : {}", stats.corners_streamed);
    println!("stats frames sent    : {}", stats.stats_frames);
    println!("sessions degraded    : {}", stats.sessions_degraded);
    println!("degrade vdd steps    : {}", stats.degrade_vdd_steps);
    println!("degrade det. swaps   : {}", stats.degrade_detector_swaps);
    println!("degrade recoveries   : {}", stats.degrade_recoveries);
    println!(
        "engines compiled/reused: {}/{}",
        stats.pool.engines_created, stats.pool.engines_reused
    );
    Ok(Json::obj(vec![
        ("listen", Json::Str(listen)),
        ("sessions_completed", Json::Num(stats.sessions_completed as f64)),
        ("sessions_failed", Json::Num(stats.sessions_failed as f64)),
        ("events_in", Json::Num(stats.events_in as f64)),
        ("events_signal", Json::Num(stats.events_signal as f64)),
        ("corners", Json::Num(stats.corners_total as f64)),
        ("peak_concurrent", Json::Num(stats.peak_concurrent as f64)),
        ("events_per_sec", Json::Num(stats.events_per_sec())),
        ("worst_lag_s", Json::Num(stats.worst_lag_s)),
        ("sessions_v2", Json::Num(stats.sessions_v2 as f64)),
        ("corners_streamed", Json::Num(stats.corners_streamed as f64)),
        ("stats_frames", Json::Num(stats.stats_frames as f64)),
        ("sessions_degraded", Json::Num(stats.sessions_degraded as f64)),
        ("degrade_vdd_steps", Json::Num(stats.degrade_vdd_steps as f64)),
        ("degrade_detector_swaps", Json::Num(stats.degrade_detector_swaps as f64)),
        ("degrade_recoveries", Json::Num(stats.degrade_recoveries as f64)),
        ("engines_created", Json::Num(stats.pool.engines_created as f64)),
        ("engines_reused", Json::Num(stats.pool.engines_reused as f64)),
    ]))
}

/// The `feed` client's sink: counts (and optionally prints) corners and
/// live stats as the server streams them back over protocol v2.
#[derive(Default)]
struct FeedSink {
    print_corners: bool,
    corners: u64,
    stats_frames: u64,
}

impl CornerSink for FeedSink {
    fn on_corner(&mut self, c: &Corner) -> Result<()> {
        self.corners += 1;
        if self.print_corners {
            println!(
                "corner seq {:<9} ({:>4},{:>4})  t {:>12} µs  score {:.5}",
                c.seq, c.ev.x, c.ev.y, c.ev.t, c.score
            );
        }
        Ok(())
    }

    fn on_stats(&mut self, s: &LiveStats) -> Result<()> {
        self.stats_frames += 1;
        // stderr so piped corner output stays clean; the v3 fields
        // (voltage, degradation level) are zero on v2 sessions
        eprintln!(
            "stats: {} in / {} signal / {} corners / {} dvfs switches / {} lut refreshes / {} mV{}",
            s.events_in,
            s.events_signal,
            s.corners_total,
            s.dvfs_switches,
            s.lut_refreshes,
            s.vdd_mv,
            if s.degrade_level > 0 {
                format!(" / degraded L{}", s.degrade_level)
            } else {
                String::new()
            }
        );
        Ok(())
    }
}

/// `feed`: stream a recording to a running `serve` instance over TCP
/// (the loopback test client: `gen-data` + `serve` + `feed` is a full
/// serving smoke test on one machine). By default a protocol-v2 session:
/// corners and live stats stream back while the recording is sent
/// (`--print-corners` prints each one); `--wire-version 1` speaks the
/// legacy summary-only protocol. Prints the server's end-of-stream
/// summary either way.
fn cmd_feed(args: &Args) -> Result<Json> {
    use nmc_tos::serve::wire::{self, Hello};
    let input = args.get("input").context("feed needs --input FILE")?;
    let connect = args.get("connect").unwrap_or("127.0.0.1:7700");
    let chunk = args.num("chunk-events", 16_384.0) as usize;
    let stream_id = args.num("stream-id", 0.0) as u32;
    let res = parse_res(args.get("res").unwrap_or("davis240"))?;
    let version = match args.get("wire-version") {
        None => wire::WIRE_V3,
        // strict parse: a typo must not silently fall back to the default
        Some(s) => s.parse::<u8>().with_context(|| format!("bad --wire-version `{s}` (1|2|3)"))?,
    };
    let hello = match version {
        1 => Hello::v1(stream_id, res),
        2 => Hello::v2(stream_id, res),
        3 => Hello::v3(stream_id, res),
        other => bail!("--wire-version {other} is not a protocol this client speaks (1|2|3)"),
    };

    let mut source = nmc_tos::events::source::open(std::path::Path::new(input), chunk)?;
    let stream = std::net::TcpStream::connect(connect)
        .with_context(|| format!("connecting to {connect}"))?;
    let mut sink = FeedSink { print_corners: args.flag("print-corners"), ..FeedSink::default() };
    let t0 = std::time::Instant::now();
    let summary = wire::feed_with_sink(stream, hello, &mut source, &mut sink)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("== fed {input} to {connect} (stream {stream_id}, chunks of {chunk}) ==");
    println!("events sent          : {}", summary.events_in);
    println!("signal after STCF    : {}", summary.events_signal);
    println!("corners tagged       : {}", summary.corners_total);
    if hello.version >= wire::WIRE_V2 {
        println!("corners streamed     : {}", sink.corners);
        println!("stats frames         : {}", sink.stats_frames);
    }
    println!("LUT refreshes        : {}", summary.lut_refreshes);
    println!("DVFS switches        : {}", summary.dvfs_switches);
    println!("server busy          : {:.3} s", summary.wall_us as f64 / 1e6);
    println!(
        "round trip           : {:.3} s ({:.0} keps)",
        wall,
        summary.events_in as f64 / wall.max(1e-9) / 1e3
    );
    Ok(Json::obj(vec![
        ("input", Json::Str(input.into())),
        ("connect", Json::Str(connect.into())),
        ("stream_id", Json::Num(stream_id as f64)),
        ("wire_version", Json::Num(hello.version as f64)),
        ("events_in", Json::Num(summary.events_in as f64)),
        ("events_signal", Json::Num(summary.events_signal as f64)),
        ("corners", Json::Num(summary.corners_total as f64)),
        ("corners_streamed", Json::Num(sink.corners as f64)),
        ("stats_frames", Json::Num(sink.stats_frames as f64)),
        ("lut_refreshes", Json::Num(summary.lut_refreshes as f64)),
        ("dvfs_switches", Json::Num(summary.dvfs_switches as f64)),
        ("server_wall_s", Json::Num(summary.wall_us as f64 / 1e6)),
        ("roundtrip_s", Json::Num(wall)),
    ]))
}

/// Print the DVFS V/f LUT.
fn cmd_lut() -> Result<Json> {
    let lut = nmc_tos::dvfs::build_lut(&DvfsConfig::default());
    println!("== DVFS V/f lookup table ==");
    println!("{:>6} {:>12} {:>16}", "Vdd", "clock MHz", "max rate Meps");
    let mut rows = Vec::new();
    for op in &lut {
        println!("{:>6.2} {:>12.0} {:>16.1}", op.vdd, op.clock_hz / 1e6, op.max_rate / 1e6);
        rows.push(Json::Arr(vec![
            Json::Num(op.vdd),
            Json::Num(op.clock_hz),
            Json::Num(op.max_rate),
        ]));
    }
    Ok(Json::Arr(rows))
}

/// Ablation grid (DESIGN.md §Extensions): pipeline x DVFS x patch size x
/// threshold x STCF — which design choices buy what.
fn cmd_ablate(args: &Args) -> Result<Json> {
    use nmc_tos::nmc::floorplan::CircuitInventory;
    let n_events = args.num("events", 120_000.0) as usize;

    println!("== ablation: pipeline x voltage (7x7 patch latency, ns) ==");
    println!("{:>6} {:>14} {:>14} {:>10}", "Vdd", "pipelined", "unpipelined", "gain");
    for mv in [600u32, 800, 1000, 1200] {
        let t = TimingModel::at(mv as f64 / 1000.0);
        let a = t.patch_latency_pipelined_ns(calib::PATCH);
        let b = t.patch_latency_unpipelined_ns(calib::PATCH);
        println!("{:>6.2} {:>14.1} {:>14.1} {:>9.2}x", mv as f64 / 1000.0, a, b, b / a);
    }

    println!("\n== ablation: patch size (throughput @1.2 V, Meps) ==");
    println!("{:>8} {:>14} {:>14}", "patch", "NMC+pipe", "conventional");
    for p in [3usize, 5, 7, 9, 11] {
        let t = TimingModel::at(1.2);
        let nmc = 1e9 / t.patch_latency_pipelined_ns(p);
        let conv_cycles = calib::CONV_CYCLES_PER_PATCH * (p * p) as f64 / 49.0;
        let conv = calib::CONV_CLOCK_NOM_HZ / conv_cycles;
        println!("{:>7}px {:>14.1} {:>14.2}", p, nmc / 1e6, conv / 1e6);
    }

    println!("\n== ablation: area — simplified MOL/CMP vs 28T full adders ==");
    for (name, res) in [("DAVIS240", Resolution::DAVIS240), ("HD720", Resolution::HD720)] {
        let inv = CircuitInventory::for_resolution(res);
        println!(
            "{:<10} ours {:>7.3} mm2   28T-FA {:>7.3} mm2   array fraction {:>4.1} %",
            name,
            inv.area_mm2(),
            inv.area_mm2_with_28t_fas(),
            inv.array_fraction() * 100.0
        );
    }

    // STCF + detection-quality ablation needs the engine
    println!("\n== ablation: STCF & TOS threshold (AUC on shapes_dof scene) ==");
    let mut scene = SceneConfig::shapes_dof().build(42);
    let (events, gt) = scene.generate_with_gt(n_events);
    println!("{:>22} {:>8} {:>10}", "config", "AUC", "signal");
    let mut rows = Vec::new();
    for (label, stcf_on, threshold) in [
        ("stcf=on  th=225", true, 225u8),
        ("stcf=off th=225", false, 225),
        ("stcf=on  th=235", true, 235),
        ("stcf=on  th=245", true, 245),
    ] {
        let mut cfg = PipelineConfig::davis240();
        cfg.dvfs = None;
        if !stcf_on {
            cfg.stcf = None;
        }
        cfg.tos.threshold = threshold;
        let mut pipe = Pipeline::new(cfg)?;
        let report = pipe.run(&events)?;
        let auc = PrCurve::from_scores(&report.scored_events(&gt, 3.5), 101).auc();
        println!("{:>22} {:>8.3} {:>10}", label, auc, report.events_signal);
        rows.push(Json::obj(vec![
            ("config", Json::Str(label.into())),
            ("auc", Json::Num(auc)),
            ("signal", Json::Num(report.events_signal as f64)),
        ]));
    }
    Ok(Json::Arr(rows))
}

/// Render the Fig. 7 control-signal waveform at a voltage.
fn cmd_waveform(args: &Args) -> Result<Json> {
    let vdd = args.num("vdd", 1.2);
    let w = nmc_tos::nmc::waveform::row_waveform(vdd);
    println!("== Fig. 7: one-row control waveform @ {vdd} V (row = {:.2} ns) ==", w.row_ns);
    print!("{}", w.render_ascii(72));
    w.check_contracts().map_err(|e| anyhow::anyhow!(e))?;
    println!("timing contracts: OK; next row may start at {:.2} ns (pipelined)",
        w.next_row_offset_ns());
    Ok(Json::obj(vec![
        ("vdd", Json::Num(vdd)),
        ("row_ns", Json::Num(w.row_ns)),
        ("next_row_offset_ns", Json::Num(w.next_row_offset_ns())),
    ]))
}

/// Generate + save a synthetic dataset to disk (binary AER container).
fn cmd_gen_data(args: &Args) -> Result<Json> {
    let n = args.num("events", 1_000_000.0) as usize;
    let seed = args.num("seed", 42.0) as u64;
    let which = args.get("scene").unwrap_or("shapes_dof");
    let out = args.get("out").unwrap_or("results/events.bin").to_string();
    let cfg = match which {
        "shapes_dof" => SceneConfig::shapes_dof(),
        "dynamic_dof" => SceneConfig::dynamic_dof(),
        other => bail!("unknown scene {other}"),
    };
    let mut scene = cfg.build(seed);
    let events = scene.generate(n);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    nmc_tos::events::codec::save(std::path::Path::new(&out), &events)?;
    println!("wrote {n} events ({which}, seed {seed}) to {out}");
    Ok(Json::obj(vec![
        ("out", Json::Str(out)),
        ("events", Json::Num(n as f64)),
    ]))
}
