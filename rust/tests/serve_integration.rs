//! Serving must be invisible to the pipeline semantics: K concurrent
//! streams served over loopback TCP produce `RunReport`s bit-identical
//! (surface, scores, corner indices, telemetry counters) to the same
//! inputs run sequentially through `run_stream` — for the golden and
//! sharded backends, and for both protocol versions: v1 clients get the
//! summary-only session unchanged, v2 clients additionally receive
//! corner batches bit-identical to what a sequential `run_stream` with a
//! `RecordingSink` records, plus live stats at the configured interval.
//! Engine-less (eFAST detector), so these run without `make artifacts`.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Barrier};
use std::thread;

use nmc_tos::coordinator::sink::{Corner, CornerSink, LiveStats, RecordingSink};
use nmc_tos::coordinator::{BackendKind, DetectorKind, Pipeline, PipelineConfig, RunReport};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::serve::wire::{self, Hello};
use nmc_tos::serve::{ServeConfig, StreamServer};

const K: usize = 4;
const EVENTS_PER_STREAM: usize = 8_000;

fn base_cfg(backend: BackendKind) -> PipelineConfig {
    let mut cfg = PipelineConfig::test64();
    cfg.backend = backend;
    cfg.detector = DetectorKind::Fast; // SAE detector: no PJRT engine
    cfg.shards = 3;
    cfg
}

/// One TCP client: handshake, stream every chunk, hold at the barrier
/// with the stream fully sent but unfinished (so all K sessions are
/// provably concurrent), then end the stream and read the summary.
fn client(
    addr: std::net::SocketAddr,
    stream_id: u32,
    events: &[Event],
    chunk: usize,
    all_streaming: &Barrier,
) -> wire::Summary {
    let conn = TcpStream::connect(addr).unwrap();
    let mut w = BufWriter::new(conn.try_clone().unwrap());
    let mut r = BufReader::new(conn);
    // hand-rolled v1 client: the pre-v2 byte stream must keep working
    wire::write_hello(&mut w, &Hello::v1(stream_id, Resolution::TEST64)).unwrap();
    w.flush().unwrap();
    wire::read_ack(&mut r).unwrap(); // a worker owns this session now

    let mut scratch = Vec::new();
    for frame in events.chunks(chunk) {
        wire::write_frame(&mut w, &mut scratch, frame).unwrap();
    }
    w.flush().unwrap();
    // every client is past its handshake and has sent its whole stream:
    // all K sessions are open inside the server at this point
    all_streaming.wait();
    wire::write_eos(&mut w).unwrap();
    w.flush().unwrap();
    wire::read_summary(&mut r).unwrap()
}

fn check_concurrent_serving(backend: BackendKind) {
    let streams: Vec<Vec<Event>> = (0..K)
        .map(|i| SceneConfig::test64().build(500 + i as u64).generate(EVENTS_PER_STREAM))
        .collect();

    // sequential ground truth: one fresh pipeline per stream
    let want: Vec<RunReport> = streams
        .iter()
        .map(|evs| {
            let mut pipe = Pipeline::from_config_without_engine(base_cfg(backend)).unwrap();
            pipe.run(evs).unwrap()
        })
        .collect();

    let mut serve_cfg = ServeConfig::new(base_cfg(backend));
    serve_cfg.max_streams = K;
    serve_cfg.keep_reports = true;
    let server = StreamServer::new(serve_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let all_streaming = Arc::new(Barrier::new(K));
    let clients: Vec<_> = streams
        .iter()
        .enumerate()
        .map(|(i, evs)| {
            let evs = evs.clone();
            let barrier = Arc::clone(&all_streaming);
            // distinct, non-divisor chunk sizes: chunking must not matter
            let chunk = 301 + i * 157;
            thread::spawn(move || client(addr, i as u32, &evs, chunk, &barrier))
        })
        .collect();

    // accept exactly K connections on this thread, then stop listening
    server.serve(&listener, Some(K)).unwrap();
    for (i, c) in clients.into_iter().enumerate() {
        let summary = c.join().unwrap();
        assert_eq!(summary.stream_id, i as u32);
        assert_eq!(summary.events_in as usize, EVENTS_PER_STREAM, "stream {i}");
    }

    let mut reports = server.take_reports();
    let stats = server.shutdown();
    assert_eq!(reports.len(), K);
    reports.sort_by_key(|(id, _)| *id);
    for (i, (id, got)) in reports.iter().enumerate() {
        assert_eq!(*id as usize, i);
        let want = &want[i];
        assert_eq!(want.final_tos, got.final_tos, "{backend:?} stream {i}: surface diverged");
        assert_eq!(want.scores, got.scores, "{backend:?} stream {i}: scores diverged");
        assert_eq!(want.corners, got.corners, "{backend:?} stream {i}: corners diverged");
        assert_eq!(want.events_in, got.events_in, "{backend:?} stream {i}: events_in");
        assert_eq!(want.events_signal, got.events_signal, "{backend:?} stream {i}: signal");
        assert_eq!(want.corners_total, got.corners_total, "{backend:?} stream {i}: corners");
        assert_eq!(want.dvfs_switches, got.dvfs_switches, "{backend:?} stream {i}: dvfs");
        assert_eq!(want.backend, got.backend, "{backend:?} stream {i}: backend stats");
    }

    assert_eq!(stats.sessions_accepted, K as u64);
    assert_eq!(stats.sessions_completed, K as u64);
    assert_eq!(stats.sessions_failed, 0);
    assert_eq!(stats.events_in as usize, K * EVENTS_PER_STREAM);
    // the barrier guarantees every session was open at once
    assert_eq!(stats.peak_concurrent, K, "sessions were not concurrent");
}

#[test]
fn concurrent_tcp_streams_bit_identical_golden() {
    check_concurrent_serving(BackendKind::Golden);
}

#[test]
fn concurrent_tcp_streams_bit_identical_sharded() {
    check_concurrent_serving(BackendKind::Sharded);
}

#[test]
fn garbage_handshake_is_cleaned_up_and_counted() {
    let server = StreamServer::new(ServeConfig::new(base_cfg(BackendKind::Golden))).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let bad = thread::spawn(move || {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap(); // not our protocol
        // server rejects and drops; reading the summary must fail
        let mut r = BufReader::new(conn.try_clone().unwrap());
        assert!(wire::read_summary(&mut r).is_err());
    });
    server.serve(&listener, Some(1)).unwrap();
    bad.join().unwrap();

    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 1);
    assert_eq!(stats.sessions_completed, 0);
}

#[test]
fn dropped_connection_mid_stream_is_counted() {
    let server = StreamServer::new(ServeConfig::new(base_cfg(BackendKind::Golden))).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let dying = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(conn.try_clone().unwrap());
        let mut r = BufReader::new(conn);
        wire::write_hello(&mut w, &Hello::v1(9, Resolution::TEST64)).unwrap();
        w.flush().unwrap();
        wire::read_ack(&mut r).unwrap();
        let events = SceneConfig::test64().build(1).generate(500);
        let mut scratch = Vec::new();
        wire::write_frame(&mut w, &mut scratch, &events).unwrap();
        w.flush().unwrap();
        // drop without EOS: a vanished camera / killed client
    });
    server.serve(&listener, Some(1)).unwrap();
    dying.join().unwrap();

    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 1);
    assert_eq!(stats.sessions_completed, 0);
}

#[test]
fn out_of_bounds_events_fail_the_session_cleanly() {
    // a client declaring test64 but streaming events outside 64x64 must
    // fail its session (no panic, no silent row aliasing) and be counted
    let server = StreamServer::new(ServeConfig::new(base_cfg(BackendKind::Golden))).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let liar = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(conn.try_clone().unwrap());
        let mut r = BufReader::new(conn);
        wire::write_hello(&mut w, &Hello::v1(3, Resolution::TEST64)).unwrap();
        w.flush().unwrap();
        wire::read_ack(&mut r).unwrap();
        // x=100 is outside the declared 64-wide sensor
        let mut scratch = Vec::new();
        wire::write_frame(&mut w, &mut scratch, &[Event::on(100, 5, 1)]).unwrap();
        // the server may already have dropped us: remaining writes are
        // best-effort, the assertion is that no summary ever comes back
        let _ = wire::write_eos(&mut w);
        let _ = w.flush();
        assert!(wire::read_summary(&mut r).is_err());
    });
    server.serve(&listener, Some(1)).unwrap();
    liar.join().unwrap();

    let stats = server.shutdown();
    assert_eq!(stats.sessions_failed, 1);
    assert_eq!(stats.sessions_completed, 0);
}

/// Client-side collector for v2 streamed results.
#[derive(Default)]
struct Collect {
    corners: Vec<Corner>,
    stats: Vec<LiveStats>,
}

impl CornerSink for Collect {
    fn on_corner(&mut self, c: &Corner) -> anyhow::Result<()> {
        self.corners.push(*c);
        Ok(())
    }
    fn on_stats(&mut self, s: &LiveStats) -> anyhow::Result<()> {
        self.stats.push(*s);
        Ok(())
    }
}

#[test]
fn v2_client_receives_bit_identical_corner_batches() {
    // threshold 0 makes every signal event a corner: the corner stream
    // is dense, so batch building/flushing is exercised for real, and
    // the bit-identity assertion covers thousands of corners
    let mut cfg = base_cfg(BackendKind::Golden);
    cfg.corner_threshold = 0.0;
    let events = SceneConfig::test64().build(900).generate(EVENTS_PER_STREAM);

    // sequential ground truth through an external RecordingSink — the
    // acceptance contract: what the wire delivers must equal what a
    // sequential run records
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let mut want = RecordingSink::default();
    let want_report = pipe.run_with(&events, &mut want).unwrap();
    assert!(!want.corners.is_empty(), "test needs a non-empty corner stream");

    let server = StreamServer::new(ServeConfig::new(cfg)).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let evs = events.clone();
    let v2 = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        // chunk size that divides nothing: frame boundaries must not
        // show in the reassembled corner stream
        let mut src = SliceSource::new(&evs, 401);
        let mut sink = Collect::default();
        let summary =
            wire::feed_with_sink(conn, Hello::v2(7, Resolution::TEST64), &mut src, &mut sink)
                .unwrap();
        (summary, sink)
    });
    server.serve(&listener, Some(1)).unwrap();
    let (summary, got) = v2.join().unwrap();

    assert_eq!(summary.corners_total, want_report.corners_total);
    assert_eq!(got.corners.len(), want.corners.len(), "corner count over the wire");
    for (c, &idx) in got.corners.iter().zip(&want.corners) {
        assert_eq!(c.seq as usize, idx, "corner seq");
        assert_eq!(c.ev, want.signal_events[idx], "corner event");
        assert_eq!(c.score.to_bits(), want.scores[idx].to_bits(), "corner score bits");
    }

    let stats = server.shutdown();
    assert_eq!(stats.sessions_completed, 1);
    assert_eq!(stats.sessions_v2, 1);
    assert_eq!(stats.corners_streamed, want.corners.len() as u64);
}

#[test]
fn v1_and_v2_clients_get_equal_sessions_from_one_server() {
    // same events through a v1 and a v2 session of one server: the v1
    // client sees the unchanged summary-only protocol, the v2 client
    // sees the same summary plus the streamed corners
    let events = SceneConfig::test64().build(901).generate(4_000);
    let mut cfg = base_cfg(BackendKind::Golden);
    cfg.corner_threshold = 0.0;
    let server = StreamServer::new(ServeConfig::new(cfg)).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let evs = events.clone();
    let v1 = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        let mut src = SliceSource::new(&evs, 512);
        wire::feed(conn, Hello::v1(1, Resolution::TEST64), &mut src).unwrap()
    });
    server.serve(&listener, Some(1)).unwrap();
    let s1 = v1.join().unwrap();

    let evs = events.clone();
    let v2 = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        let mut src = SliceSource::new(&evs, 512);
        let mut sink = Collect::default();
        let s = wire::feed_with_sink(conn, Hello::v2(2, Resolution::TEST64), &mut src, &mut sink)
            .unwrap();
        (s, sink)
    });
    server.serve(&listener, Some(1)).unwrap();
    let (s2, got) = v2.join().unwrap();

    assert_eq!(s1.events_in, s2.events_in);
    assert_eq!(s1.events_signal, s2.events_signal);
    assert_eq!(s1.corners_total, s2.corners_total);
    assert_eq!(got.corners.len() as u64, s2.corners_total);
    assert!(got.stats.is_empty(), "no stats frames without --stats-interval");

    let stats = server.shutdown();
    assert_eq!(stats.sessions_completed, 2);
    assert_eq!(stats.sessions_v2, 1, "only the v2 session streams");
}

#[test]
fn v2_sessions_stream_live_stats_at_the_configured_interval() {
    let mut cfg = base_cfg(BackendKind::Golden);
    cfg.stats_interval_events = Some(1_000);
    let server = StreamServer::new(ServeConfig::new(cfg)).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let client = thread::spawn(move || {
        let events = SceneConfig::test64().build(902).generate(EVENTS_PER_STREAM);
        let conn = TcpStream::connect(addr).unwrap();
        let mut src = SliceSource::new(&events, 700);
        let mut sink = Collect::default();
        let summary =
            wire::feed_with_sink(conn, Hello::v2(9, Resolution::TEST64), &mut src, &mut sink)
                .unwrap();
        (summary, sink)
    });
    server.serve(&listener, Some(1)).unwrap();
    let (summary, got) = client.join().unwrap();

    // 8000 events at one snapshot per 1000: exactly 8, counters monotone,
    // and the last snapshot equals the summary's final counters
    assert_eq!(got.stats.len(), 8);
    for (i, s) in got.stats.iter().enumerate() {
        assert_eq!(s.events_in, 1_000 * (i as u64 + 1));
    }
    for w in got.stats.windows(2) {
        assert!(w[1].events_signal >= w[0].events_signal);
        assert!(w[1].corners_total >= w[0].corners_total);
    }
    let last = got.stats.last().unwrap();
    assert_eq!(last.events_in, summary.events_in);
    assert_eq!(last.events_signal, summary.events_signal);
    assert_eq!(last.corners_total, summary.corners_total);
    assert_eq!(last.dvfs_switches, summary.dvfs_switches);
    assert_eq!(last.lut_refreshes, summary.lut_refreshes);

    assert_eq!(server.shutdown().stats_frames, 8);
}

#[test]
fn mixed_tcp_and_local_sessions() {
    // the same server serves an in-process session and a TCP session;
    // both must match their sequential references
    let events = SceneConfig::test64().build(77).generate(4_000);
    let mut pipe = Pipeline::from_config_without_engine(base_cfg(BackendKind::Golden)).unwrap();
    let want = pipe.run(&events).unwrap();

    let mut serve_cfg = ServeConfig::new(base_cfg(BackendKind::Golden));
    serve_cfg.keep_reports = true;
    let server = StreamServer::new(serve_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // local session through the public submit API
    let local = server
        .submit(
            1,
            Resolution::TEST64,
            Box::new(SceneConfig::test64().build(77).into_source(4_000, 333)),
        )
        .unwrap();

    // TCP session with the same events via the feed client — a v2
    // session whose streamed results the plain `feed` wrapper discards
    let tcp_events = events.clone();
    let tcp = thread::spawn(move || {
        let conn = TcpStream::connect(addr).unwrap();
        let mut src = nmc_tos::events::source::SliceSource::new(&tcp_events, 512);
        wire::feed(conn, Hello::v2(2, Resolution::TEST64), &mut src).unwrap()
    });
    server.serve(&listener, Some(1)).unwrap();

    let local_report = local.join().unwrap();
    let summary = tcp.join().unwrap();
    assert_eq!(summary.events_in as usize, 4_000);
    assert_eq!(want.final_tos, local_report.final_tos);
    assert_eq!(want.scores, local_report.scores);

    let reports = server.take_reports();
    let tcp_report = &reports.iter().find(|(id, _)| *id == 2).unwrap().1;
    assert_eq!(want.final_tos, tcp_report.final_tos);
    assert_eq!(want.scores, tcp_report.scores);
    assert_eq!(server.shutdown().sessions_completed, 2);
}
