//! The public-dataset AUC harness against the checked-in fixture
//! manifest: three datasets that are the *same* canonical recording in
//! three formats (AEDAT4/EVT2/EVT3), so every (backend, detector) cell
//! must score identically across them — and the rendered report must be
//! byte-identical across repeat runs (the property the CI `dataset-smoke`
//! lane checks by `cmp`-ing two binary invocations).

use std::path::{Path, PathBuf};

use nmc_tos::eval::{run_dataset_eval, DatasetEvalConfig};

fn manifest() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/datasets/manifest.json")
}

#[test]
fn smoke_eval_scores_the_fixture_manifest_deterministically() {
    let mut cfg = DatasetEvalConfig::smoke(manifest());
    if cfg!(miri) {
        cfg.max_events = Some(300);
    }
    let rep = run_dataset_eval(&cfg).unwrap();

    // 3 datasets x 2 backends x 2 detectors, all sharing one label file
    assert_eq!(rep.points.len(), 12);
    assert_eq!(rep.labels.len(), 3);
    let n_labels = rep.labels["fixture-aedat4"];
    assert!(n_labels > 0);
    assert!(rep.labels.values().all(|&n| n == n_labels));

    for p in &rep.points {
        assert!(p.events_in > 0, "{}/{}/{}", p.dataset, p.backend, p.detector);
        assert_eq!(p.scored, p.events_signal, "one (score, label) pair per signal event");
        assert!(
            p.positives > 0,
            "{}/{}/{}: the fixture labels must match fixture events",
            p.dataset,
            p.backend,
            p.detector
        );
        assert!(p.auc.is_finite() && (0.0..=1.0).contains(&p.auc));
        assert!(p.best_f1 > 0.0);
    }

    // cross-format agreement: the three datasets decode to the same
    // stream, so each (backend, detector) cell is bit-identical across
    // them — AUC included
    for a in &rep.points {
        for b in rep.points.iter().filter(|b| {
            b.dataset != a.dataset && b.backend == a.backend && b.detector == a.detector
        }) {
            let cell = format!("{}/{} ({} vs {})", a.backend, a.detector, a.dataset, b.dataset);
            assert_eq!(a.events_in, b.events_in, "{cell}: events_in");
            assert_eq!(a.events_signal, b.events_signal, "{cell}: events_signal");
            assert_eq!(a.corners, b.corners, "{cell}: corners");
            assert_eq!(a.positives, b.positives, "{cell}: positives");
            assert_eq!(a.auc.to_bits(), b.auc.to_bits(), "{cell}: AUC");
            assert_eq!(a.best_f1.to_bits(), b.best_f1.to_bits(), "{cell}: best F1");
        }
    }

    // the determinism bar: repeat runs render the same bytes
    let a = rep.to_json().render();
    let b = run_dataset_eval(&cfg).unwrap().to_json().render();
    assert_eq!(a, b, "repeat runs must render byte-identically");
    assert!(a.contains("\"harness\":\"dataset-eval\""));
}

#[test]
fn full_preset_runs_the_fixture_manifest_end_to_end() {
    // the non-smoke preset (whole recordings, default chunking) over the
    // same manifest: 3 datasets x nmc x harris
    let mut cfg = DatasetEvalConfig::new(manifest());
    if cfg!(miri) {
        cfg.max_events = Some(300);
    }
    let rep = run_dataset_eval(&cfg).unwrap();
    assert_eq!(rep.points.len(), 3);
    for p in &rep.points {
        assert_eq!(p.backend, "nmc-tos");
        if !cfg!(miri) {
            assert_eq!(p.events_in, 1260, "whole fixture recording, uncapped");
        }
        assert!(p.positives > 0);
    }
}

#[test]
fn chunk_size_does_not_change_the_report() {
    // streamed decode at any chunk size is bit-identical, so the report
    // must be too — the EVT sources re-chunk, AEDAT4 ignores it
    let mk = |chunk: usize| {
        let mut cfg = DatasetEvalConfig::smoke(manifest());
        cfg.chunk_events = chunk;
        if cfg!(miri) {
            cfg.max_events = Some(300);
            cfg.backends.truncate(1);
            cfg.detectors.truncate(1);
        }
        cfg
    };
    let want = run_dataset_eval(&mk(4096)).unwrap().to_json().render();
    let chunks: &[usize] = if cfg!(miri) { &[13] } else { &[1, 13, 100_000] };
    for &chunk in chunks {
        let got = run_dataset_eval(&mk(chunk)).unwrap().to_json().render();
        assert_eq!(want, got, "chunk {chunk} changed the report bytes");
    }
}
