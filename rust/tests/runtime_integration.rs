//! Integration tests over the PJRT runtime: load the AOT artifacts, run
//! the Harris graph, and cross-check numerics against an independent Rust
//! implementation of the same operator.
//!
//! Requires `make artifacts` (skipped gracefully otherwise — CI runs
//! `make test` which builds them first).

use nmc_tos::events::Resolution;
use nmc_tos::runtime::{default_artifact_dir, HarrisEngine, Manifest};
use nmc_tos::tos::{TosConfig, TosSurface};
use nmc_tos::util::rng::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = default_artifact_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

/// Independent golden Harris (plain Rust, same math as python ref.py):
/// pad-by-4 + two valid separable 5x5 stencils + min-max normalize.
fn harris_golden(frame: &[f32], h: usize, w: usize) -> Vec<f32> {
    let smooth = [1.0f64 / 16.0, 4.0 / 16.0, 6.0 / 16.0, 4.0 / 16.0, 1.0 / 16.0];
    let deriv = [-1.0f64 / 6.0, -2.0 / 6.0, 0.0, 2.0 / 6.0, 1.0 / 6.0];
    let gauss = smooth; // same binomial taps, normalized
    let ph = h + 8;
    let pw = w + 8;
    let mut img = vec![0.0f64; ph * pw];
    for y in 0..h {
        for x in 0..w {
            img[(y + 4) * pw + (x + 4)] = frame[y * w + x] as f64 / 255.0;
        }
    }
    let conv_valid = |src: &[f32], sh: usize, sw: usize, kr: &[f64; 5], kc: &[f64; 5]| -> Vec<f32> {
        // rows then cols, f32 accumulation to mirror the XLA kernel
        let oh = sh - 4;
        let mut tmp = vec![0.0f32; oh * sw];
        for y in 0..oh {
            for x in 0..sw {
                let mut s = 0.0f32;
                for (k, &t) in kr.iter().enumerate() {
                    s += t as f32 * src[(y + k) * sw + x];
                }
                tmp[y * sw + x] = s;
            }
        }
        let ow = sw - 4;
        let mut out = vec![0.0f32; oh * ow];
        for y in 0..oh {
            for x in 0..ow {
                let mut s = 0.0f32;
                for (k, &t) in kc.iter().enumerate() {
                    s += t as f32 * tmp[y * sw + x + k];
                }
                out[y * ow + x] = s;
            }
        }
        out
    };
    let img32: Vec<f32> = img.iter().map(|&v| v as f32).collect();
    let ix = conv_valid(&img32, ph, pw, &smooth, &deriv);
    let iy = conv_valid(&img32, ph, pw, &deriv, &smooth);
    let gh = ph - 4;
    let gw = pw - 4;
    let mul = |a: &[f32], b: &[f32]| -> Vec<f32> { a.iter().zip(b).map(|(x, y)| x * y).collect() };
    let sxx = conv_valid(&mul(&ix, &ix), gh, gw, &gauss, &gauss);
    let syy = conv_valid(&mul(&iy, &iy), gh, gw, &gauss, &gauss);
    let sxy = conv_valid(&mul(&ix, &iy), gh, gw, &gauss, &gauss);
    let mut r = vec![0.0f32; h * w];
    for i in 0..h * w {
        let det = sxx[i] * syy[i] - sxy[i] * sxy[i];
        let tr = sxx[i] + syy[i];
        r[i] = det - 0.04 * tr * tr;
    }
    let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if hi > lo {
        for v in &mut r {
            *v = (*v - lo) / (hi - lo);
        }
    } else {
        r.fill(0.0);
    }
    r
}

#[test]
fn engine_loads_and_reports_shape() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = HarrisEngine::load(&m, "test64").unwrap();
    assert_eq!((engine.height, engine.width), (64, 64));
    assert_eq!(engine.platform(), "cpu");
}

#[test]
fn engine_numerics_match_independent_golden() {
    let Some(m) = manifest_or_skip() else { return };
    let mut engine = HarrisEngine::load(&m, "test64").unwrap();
    let mut rng = Rng::seed_from(11);
    for case in 0..3 {
        // TOS-like frame: sparse blocks of 225..255
        let mut frame = vec![0.0f32; 64 * 64];
        for _ in 0..6 {
            let cx = rng.below(64) as usize;
            let cy = rng.below(64) as usize;
            let v = 225 + rng.below(31) as usize;
            for y in cy.saturating_sub(3)..(cy + 4).min(64) {
                for x in cx.saturating_sub(3)..(cx + 4).min(64) {
                    frame[y * 64 + x] = v as f32;
                }
            }
        }
        let got = engine.compute(&frame).unwrap();
        let want = harris_golden(&frame, 64, 64);
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-2, "case {case}: max diff {max_diff}");
        // the engine's peak must be a near-peak of the golden map too
        // (exact argmax can swap between near-ties under f32 reordering)
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        let got_peak_in_want = want[am(&got)];
        let want_peak = want[am(&want)];
        assert!(
            (want_peak - got_peak_in_want).abs() < 3e-2,
            "case {case}: engine peak is not a golden near-peak ({got_peak_in_want} vs {want_peak})"
        );
    }
    assert_eq!(engine.executions, 3);
}

#[test]
fn engine_flat_frame_yields_zero_lut() {
    let Some(m) = manifest_or_skip() else { return };
    let mut engine = HarrisEngine::load(&m, "test64").unwrap();
    let lut = engine.compute(&vec![0.0f32; 64 * 64]).unwrap();
    assert!(lut.iter().all(|&v| v.abs() < 1e-6));
}

#[test]
fn engine_rejects_wrong_size() {
    let Some(m) = manifest_or_skip() else { return };
    let mut engine = HarrisEngine::load(&m, "test64").unwrap();
    assert!(engine.compute(&vec![0.0f32; 100]).is_err());
}

#[test]
fn engine_highlights_tos_corners() {
    // Feed a real TOS (from the golden surface) and check the LUT peaks
    // near the TOS structure corners.
    let Some(m) = manifest_or_skip() else { return };
    let mut engine = HarrisEngine::load(&m, "test64").unwrap();
    let mut surf = TosSurface::new(Resolution::TEST64, TosConfig::default()).unwrap();
    // draw an L: two strokes of events meeting at (32, 32)
    let mut t = 0u64;
    for i in 0..16u16 {
        surf.update(&nmc_tos::events::Event::on(32 - i, 32, t));
        t += 1;
        surf.update(&nmc_tos::events::Event::on(32, 32 - i, t));
        t += 1;
    }
    let lut = engine.compute_u8(surf.data()).unwrap();
    let (mut best, mut bx, mut by) = (0.0f32, 0usize, 0usize);
    for y in 0..64 {
        for x in 0..64 {
            if lut[y * 64 + x] > best {
                best = lut[y * 64 + x];
                bx = x;
                by = y;
            }
        }
    }
    let d = (bx as i32 - 32).abs() + (by as i32 - 32).abs();
    assert!(d <= 6, "LUT peak at ({bx},{by}) not near the L-corner (32,32)");
}

#[test]
fn davis240_engine_full_resolution() {
    let Some(m) = manifest_or_skip() else { return };
    let mut engine = HarrisEngine::load(&m, "davis240").unwrap();
    assert_eq!((engine.height, engine.width), (180, 240));
    let mut frame = vec![0.0f32; 180 * 240];
    for y in 60..100 {
        for x in 100..160 {
            frame[y * 240 + x] = 255.0;
        }
    }
    let lut = engine.compute(&frame).unwrap();
    assert_eq!(lut.len(), 180 * 240);
    let hi = lut.iter().cloned().fold(0.0f32, f32::max);
    assert!((hi - 1.0).abs() < 1e-5, "normalized max {hi}");
}
