//! Randomized property tests over the simulator's core invariants, run
//! with the in-tree `util::proptest` harness (offline stand-in for the
//! proptest crate; failures print a one-line reproducing seed).

use nmc_tos::conventional::ConventionalTos;
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::dvfs::{DvfsConfig, DvfsController};
use nmc_tos::events::{stream, Event, Polarity, Resolution};
use nmc_tos::nmc::{calib, NmcConfig, NmcMacro};
use nmc_tos::stcf::{Stcf, StcfConfig};
use nmc_tos::tos::backend::{decrement_clamp, decrement_clamp_scalar, PatchRect};
use nmc_tos::tos::kernel::{available_paths, decrement_clamp_with};
use nmc_tos::tos::{encoding, ShardedTos, TosBackend, TosConfig, TosSurface};
use nmc_tos::util::proptest::check;
use nmc_tos::util::rng::Rng;

fn random_events(rng: &mut Rng, n: usize, res: Resolution) -> Vec<Event> {
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += rng.below(200);
            Event::new(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                t,
                if rng.chance(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect()
}

/// PROPERTY: the NMC macro (5-bit datapath, gate-level MOL/CMP/WR) is
/// bit-identical to the golden 8-bit TOS for any event stream at any
/// error-free voltage.
#[test]
fn prop_nmc_equals_golden_tos() {
    check(0xA11CE, 25, |rng| {
        let res = Resolution::TEST64;
        let patch = [3u16, 5, 7, 9][rng.below(4) as usize];
        let threshold = 225 + rng.below(20) as u8;
        let tos_cfg = TosConfig { patch, threshold };
        let vdd = rng.range_f64(0.63, 1.2); // error-free region
        let cfg = NmcConfig {
            tos: tos_cfg,
            pipelined: rng.chance(0.5),
            vdd,
            inject_errors: true, // injector active but p(err)=0 above 0.63 V
            seed: rng.next_u64(),
        };
        let mut mac = NmcMacro::new(res, cfg).unwrap();
        let mut golden = TosSurface::new(res, tos_cfg).unwrap();
        for e in random_events(rng, 1500, res) {
            mac.process(&e);
            golden.update(&e);
        }
        assert_eq!(mac.snapshot_u8(), golden.data().to_vec());
    });
}

/// PROPERTY: every value the golden TOS ever holds is representable in the
/// 5-bit encoding (the invariant that justifies dropping 3 bits on-chip).
#[test]
fn prop_tos_values_always_representable() {
    check(0xB0B, 20, |rng| {
        let res = Resolution::TEST64;
        let threshold = 225 + rng.below(25) as u8;
        let mut surf = TosSurface::new(res, TosConfig { patch: 7, threshold }).unwrap();
        for e in random_events(rng, 2000, res) {
            surf.update(&e);
            debug_assert!(true);
        }
        for &v in surf.data() {
            assert!(
                v == 0 || v >= threshold,
                "value {v} below TH {threshold} survived"
            );
            assert!(encoding::representable(v) || v >= 225, "unrepresentable {v}");
        }
    });
}

/// PROPERTY: every [`nmc_tos::tos::TosBackend`] — conventional, NMC at an
/// error-free voltage, and the sharded parallel model at any shard count —
/// is bit-exact against the golden `TosSurface` on random event streams,
/// including patch clipping at the sensor borders and patches straddling
/// shard boundaries.
#[test]
fn prop_all_backends_bit_exact() {
    check(0xBACE2D, 12, |rng| {
        let res = if rng.chance(0.5) { Resolution::TEST64 } else { Resolution::new(96, 48) };
        let patch = [3u16, 5, 7, 9][rng.below(4) as usize];
        let threshold = 225 + rng.below(20) as u8;
        let cfg = TosConfig { patch, threshold };
        let mut events = random_events(rng, 2_000, res);
        // pin events at all four corners so border clipping always runs
        let t0 = events.last().map_or(0, |e| e.t);
        events.push(Event::on(0, 0, t0 + 1));
        events.push(Event::on(res.width - 1, 0, t0 + 2));
        events.push(Event::on(0, res.height - 1, t0 + 3));
        events.push(Event::on(res.width - 1, res.height - 1, t0 + 4));

        let mut golden = TosSurface::new(res, cfg).unwrap();
        golden.update_batch(&events);

        let mut conv = ConventionalTos::new(res, cfg, 1.2).unwrap();
        for e in &events {
            conv.process(e);
        }
        assert_eq!(golden.data(), conv.surface().data(), "conventional diverged");

        let vdd = rng.range_f64(0.63, 1.2); // error-free region
        let mut mac = NmcMacro::new(
            res,
            NmcConfig { tos: cfg, pipelined: rng.chance(0.5), vdd, ..NmcConfig::default() },
        )
        .unwrap();
        mac.process_batch(&events);
        assert_eq!(golden.data(), &mac.snapshot_u8()[..], "NMC diverged at {vdd} V");

        for shards in [1usize, 2, 3, 5, 8, res.height as usize] {
            let mut sharded = ShardedTos::new(res, cfg, shards).unwrap();
            // split the stream so both the batch path and the single-event
            // path are exercised
            let cut = events.len() / 3;
            sharded.process_batch(&events[..cut]);
            for e in &events[cut..2 * cut] {
                nmc_tos::tos::TosBackend::process(&mut sharded, e);
            }
            sharded.process_batch(&events[2 * cut..]);
            assert_eq!(golden.data(), sharded.data(), "sharded diverged at {shards} shards");
        }
    });
}

/// PROPERTY: the SWAR-vectorized decrement/clamp kernel is bit-exact
/// against the scalar reference loop on random row windows — every width
/// (1-pixel rows through multi-lane rows), every alignment, rects
/// touching every border of the window, shard-style `base_row` offsets,
/// and the full 0..=255 threshold range (the software backends accept any
/// `TH`, not just the NMC floor).
#[test]
fn prop_vector_kernel_equals_scalar() {
    check(0x51AD0, 80, |rng| {
        let width = 1 + rng.below(40) as usize;
        let rows = 1 + rng.below(12) as usize;
        let base_row = rng.below(300) as u16;
        let data: Vec<u8> = (0..width * rows).map(|_| rng.below(256) as u8).collect();
        let x0 = rng.below(width as u64) as u16;
        let x1 = x0 + rng.below(width as u64 - x0 as u64) as u16;
        let y0 = base_row + rng.below(rows as u64) as u16;
        let y1 = y0 + rng.below(rows as u64 - (y0 - base_row) as u64) as u16;
        let th = rng.below(256) as u8;
        let rect = PatchRect { x0, x1, y0, y1 };
        let mut a = data.clone();
        let mut b = data.clone();
        decrement_clamp(&mut a, width, base_row, rect, th);
        decrement_clamp_scalar(&mut b, width, base_row, rect, th);
        assert_eq!(a, b, "w={width} rows={rows} base={base_row} rect={rect:?} th={th}");
        // and every explicitly-dispatched path this host can run, not just
        // the startup selection
        for path in available_paths() {
            let mut c = data.clone();
            decrement_clamp_with(path, &mut c, width, base_row, rect, th);
            assert_eq!(c, b, "{path}: w={width} base={base_row} rect={rect:?} th={th}");
        }
    });
}

/// PROPERTY: the vectorized masked-lane `Stcf::check` is observationally
/// identical to the original early-exit nested-loop classifier
/// (`check_scalar`) on random event streams — same per-event verdicts and
/// same telemetry — for any radius/support/window draw, including
/// non-monotone timestamps (future neighbours must still count, as in the
/// scalar code's saturating subtraction).
#[test]
fn prop_stcf_vectorized_equals_scalar() {
    check(0x57CF2, 20, |rng| {
        let res = Resolution::TEST64;
        let cfg = StcfConfig {
            tw_us: rng.below(20_000),
            radius: rng.below(4) as u16,
            support: rng.below(5) as u32,
            any_polarity: true,
        };
        let mut vec = Stcf::new(res, cfg);
        let mut scl = Stcf::new(res, cfg);
        let mut events = random_events(rng, 1_500, res);
        // splice in out-of-order timestamps so "future" neighbours occur
        for i in (0..events.len()).step_by(97) {
            events[i].t = rng.below(40_000);
        }
        for (i, e) in events.iter().enumerate() {
            assert_eq!(vec.check(e), scl.check_scalar(e), "event {i} cfg {cfg:?}");
        }
        assert_eq!(vec.stats(), scl.stats());
    });
}

/// PROPERTY: the three snapshot APIs (`tos_view`, `snapshot_into`,
/// `snapshot_u8`) agree with each other and with the old `snapshot_u8`
/// semantics — the golden surface contents — for every backend, and
/// `snapshot_into` fixes up a wrongly-sized caller buffer.
#[test]
fn prop_snapshot_apis_agree_for_every_backend() {
    check(0x5AA95, 8, |rng| {
        let res = Resolution::TEST64;
        let cfg = TosConfig { patch: 7, threshold: 225 + rng.below(20) as u8 };
        let events = random_events(rng, 1200, res);
        let mut golden = TosSurface::new(res, cfg).unwrap();
        golden.update_batch(&events);
        let backends: Vec<Box<dyn TosBackend>> = vec![
            Box::new(TosSurface::new(res, cfg).unwrap()),
            Box::new(ConventionalTos::new(res, cfg, 1.2).unwrap()),
            Box::new(NmcMacro::new(res, NmcConfig { tos: cfg, ..NmcConfig::default() }).unwrap()),
            Box::new(ShardedTos::new(res, cfg, 1 + rng.below(8) as usize).unwrap()),
        ];
        for mut b in backends {
            b.process_batch(&events);
            assert_eq!(b.tos_view(), golden.data(), "{} tos_view", b.name());
            assert_eq!(b.snapshot_u8(), golden.data(), "{} snapshot_u8", b.name());
            let mut out = vec![0xAB; 3]; // wrong size on purpose
            b.snapshot_into(&mut out);
            assert_eq!(out, golden.data(), "{} snapshot_into", b.name());
            // reset erases the view too
            b.reset();
            assert!(b.tos_view().iter().all(|&v| v == 0), "{} reset view", b.name());
        }
    });
}

/// PROPERTY: conventional baseline and NMC macro produce identical
/// surfaces (they implement the same Algorithm 1; only cost models differ).
#[test]
fn prop_conventional_equals_nmc_functionally() {
    check(0xC0DE, 15, |rng| {
        let res = Resolution::TEST64;
        let cfg = TosConfig::default();
        let mut conv = ConventionalTos::new(res, cfg, 1.2).unwrap();
        let mut mac = NmcMacro::new(res, NmcConfig::default()).unwrap();
        for e in random_events(rng, 1000, res) {
            conv.process(&e);
            mac.process(&e);
        }
        assert_eq!(conv.surface().data(), &mac.snapshot_u8()[..]);
    });
}

/// PROPERTY: NMC latency/energy accounting is consistent — totals equal
/// the sum of per-event costs, and pipelined latency is strictly less than
/// unpipelined for the same stream.
#[test]
fn prop_cost_accounting_consistent() {
    check(0xFEE, 15, |rng| {
        let res = Resolution::TEST64;
        let events = random_events(rng, 500, res);
        let run = |pipelined: bool| {
            let mut mac = NmcMacro::new(
                res,
                NmcConfig { pipelined, ..NmcConfig::default() },
            )
            .unwrap();
            let mut sum_lat = 0.0;
            let mut sum_e = 0.0;
            for e in &events {
                let c = mac.process(e);
                sum_lat += c.latency_ns;
                sum_e += c.energy_pj;
            }
            let s = mac.stats();
            assert!((s.busy_ns - sum_lat).abs() < 1e-6);
            assert!((s.energy_pj - sum_e).abs() < 1e-6);
            s
        };
        let piped = run(true);
        let unpiped = run(false);
        assert!(piped.busy_ns < unpiped.busy_ns);
        assert_eq!(piped.energy_pj, unpiped.energy_pj, "pipeline must not change energy");
    });
}

/// PROPERTY: the DVFS rate estimate converges to the true rate of a
/// constant stream within 10 %, and the chosen operating point always has
/// capacity >= estimate (with headroom) unless pinned at max.
#[test]
fn prop_dvfs_estimate_and_capacity() {
    check(0xD7F5, 15, |rng| {
        let rate_eps = rng.range_f64(5e3, 40e6);
        let cfg = DvfsConfig::default();
        let mut ctrl = DvfsController::new(cfg);
        let dt_ns = (1e9 / rate_eps) as u64;
        let mut t_ns = 0u64;
        // run for 6 windows
        let end_ns = 6 * cfg.tw_us * 1000;
        while t_ns < end_ns {
            ctrl.on_event(t_ns / 1000);
            t_ns += dt_ns.max(1);
        }
        let est = ctrl.estimated_rate().expect("estimate after 6 windows");
        assert!(
            (est - rate_eps).abs() / rate_eps < 0.10,
            "estimate {est} vs true {rate_eps}"
        );
        let op = ctrl.operating_point();
        let need = est * cfg.headroom;
        let max_op = 63.2e6;
        assert!(
            op.max_rate >= need || op.max_rate > max_op * 0.99,
            "capacity {} below need {need}",
            op.max_rate
        );
    });
}

/// PROPERTY: STCF is deterministic, order-preserving, and never *creates*
/// events; disabling it (support=0) passes everything.
#[test]
fn prop_stcf_filters_subset_in_order() {
    check(0x57CF, 15, |rng| {
        let res = Resolution::TEST64;
        let events = random_events(rng, 1500, res);
        let cfg = StcfConfig {
            tw_us: 1 + rng.below(20_000),
            radius: 1 + rng.below(2) as u16,
            support: 1 + rng.below(3) as u32,
            any_polarity: true,
        };
        let mut f = Stcf::new(res, cfg);
        let out = f.filter(&events);
        assert!(out.len() <= events.len());
        // subset & order: every output event appears in input order
        let mut idx = 0usize;
        for oe in &out {
            while idx < events.len() && events[idx] != *oe {
                idx += 1;
            }
            assert!(idx < events.len(), "filtered event not found in order");
            idx += 1;
        }
        // support=0 passes everything
        let mut f0 = Stcf::new(res, StcfConfig { support: 0, ..cfg });
        assert_eq!(f0.filter(&events).len(), events.len());
    });
}

/// PROPERTY: synthetic scene streams are valid (sorted, in-bounds) and
/// deterministic per seed for any config draw.
#[test]
fn prop_scene_streams_valid() {
    check(0x5CE4E, 8, |rng| {
        let mut cfg = SceneConfig::test64();
        cfg.shapes = 1 + rng.below(5) as usize;
        cfg.signal_rate = rng.range_f64(2e4, 4e5);
        cfg.noise_rate = rng.range_f64(0.0, 5e4);
        let seed = rng.next_u64();
        let mut scene = cfg.clone().build(seed);
        let n = 4_000 + rng.below(10_000) as usize;
        let evs = scene.generate(n);
        assert_eq!(evs.len(), n);
        stream::validate(&evs, cfg.res).unwrap();
        let mut scene2 = cfg.build(seed);
        assert_eq!(scene2.generate(n), evs, "not deterministic");
    });
}

/// PROPERTY: the alpha-power timing model is internally consistent for any
/// voltage in range: pipelined < unpipelined < conventional-per-event,
/// and throughput * latency == 1.
#[test]
fn prop_timing_model_consistency() {
    check(0x71E, 30, |rng| {
        let v = rng.range_f64(0.6, 1.2);
        let t = nmc_tos::nmc::timing::TimingModel::at(v);
        let piped = t.patch_latency_pipelined_ns(calib::PATCH);
        let unpiped = t.patch_latency_unpipelined_ns(calib::PATCH);
        let conv = nmc_tos::conventional::ConventionalModel::at(v).event_latency_ns(49);
        assert!(piped < unpiped && unpiped < conv, "{piped} {unpiped} {conv} @ {v}");
        let rate = t.max_event_rate();
        assert!((rate * piped * 1e-9 - 1.0).abs() < 1e-9);
    });
}
