//! Adaptive degradation under overload: a served stream whose event
//! timestamps outrun realtime must make the server *shed* work — step the
//! supply voltage down, then swap to the cheaper fallback detector —
//! instead of lagging or dropping events, and must climb back to the
//! nominal operating point once the input calms down. The whole episode
//! is observable in the per-session v3 stats frames and the aggregate
//! [`ServerStats`] counters, and no event is ever lost.
//!
//! Engine-less (eHarris primary / eFAST fallback), so this runs without
//! `make artifacts`. The spike scene comes from the enumerative scenario
//! grid's `Overload` rate point; timestamps are then compressed so the
//! "camera" bursts far beyond what any realtime budget can absorb —
//! keeping the lag signal machine-independent.

use std::net::{TcpListener, TcpStream};
use std::thread;

use nmc_tos::coordinator::sink::{Corner, CornerSink, LiveStats};
use nmc_tos::coordinator::{BackendKind, DetectorKind, PipelineConfig};
use nmc_tos::datasets::scenarios::{Motion, NoiseLevel, RateLevel, ScenarioGrid};
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::serve::wire::{self, Hello};
use nmc_tos::serve::{DegradeConfig, ServeConfig, StreamServer};

/// Spike length (events) and the event-time span they are squeezed into.
const SPIKE_EVENTS: usize = 400_000;
const SPIKE_SPAN_US: u64 = 5_000;
/// Calm tail: sparse events whose timestamps sprint ahead of the wall
/// clock, driving the measured lag strongly negative.
const TAIL_EVENTS: usize = 100_000;
const TAIL_GAP_US: u64 = 2_000;

/// Client-side collector for v3 streamed results.
#[derive(Default)]
struct Collect {
    corners: u64,
    stats: Vec<LiveStats>,
}

impl CornerSink for Collect {
    fn on_corner(&mut self, _c: &Corner) -> anyhow::Result<()> {
        self.corners += 1;
        Ok(())
    }
    fn on_stats(&mut self, s: &LiveStats) -> anyhow::Result<()> {
        self.stats.push(*s);
        Ok(())
    }
}

/// Overload burst followed by a calm tail, from the scenario grid.
fn overload_then_calm() -> Vec<Event> {
    let grid = ScenarioGrid {
        motions: vec![Motion::Fast],
        rates: vec![RateLevel::Overload],
        noises: vec![NoiseLevel::Noisy],
        resolutions: vec![Resolution::TEST64],
        vdds: vec![1.2],
    };
    let scenario = &grid.enumerate()[0];
    let mut events = scenario.build(7).generate(SPIKE_EVENTS + TAIL_EVENTS);
    // spike: the first SPIKE_EVENTS all inside SPIKE_SPAN_US of event
    // time — far more work per event-second than realtime allows
    for (i, e) in events[..SPIKE_EVENTS].iter_mut().enumerate() {
        e.t = i as u64 * SPIKE_SPAN_US / SPIKE_EVENTS as u64;
    }
    // tail: sparse events, each TAIL_GAP_US apart — event time races
    // ahead of the wall clock, so every governor poll reads as calm
    for (i, e) in events[SPIKE_EVENTS..].iter_mut().enumerate() {
        e.t = 2 * SPIKE_SPAN_US + i as u64 * TAIL_GAP_US;
    }
    events
}

#[test]
fn overload_degrades_sheds_and_recovers_without_drops() {
    let mut cfg = PipelineConfig::test64();
    cfg.backend = BackendKind::Nmc;
    cfg.detector = DetectorKind::EHarris; // real per-event cost to shed
    cfg.record_per_event = false;
    cfg.stats_interval_events = Some(25_000);
    let mut serve_cfg = ServeConfig::new(cfg);
    serve_cfg.max_streams = 1;
    // tight thresholds so the compressed spike trips degradation on any
    // machine: the spike freezes event time, so lag is pure wall time
    serve_cfg.degrade = Some(DegradeConfig {
        lag_shed_s: 0.02,
        lag_recover_s: 0.005,
        fallback: DetectorKind::Fast,
        ..DegradeConfig::default()
    });

    let server = StreamServer::new(serve_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let client = thread::spawn(move || {
        let events = overload_then_calm();
        let conn = TcpStream::connect(addr).unwrap();
        // small frames => many governor polls during both phases
        let mut src = SliceSource::new(&events, 2_048);
        let mut sink = Collect::default();
        let summary =
            wire::feed_with_sink(conn, Hello::v3(1, Resolution::TEST64), &mut src, &mut sink)
                .unwrap();
        (summary, sink)
    });
    server.serve(&listener, Some(1)).unwrap();
    let (summary, got) = client.join().unwrap();
    let stats = server.shutdown();

    // zero drops: every event fed came back accounted for, and every
    // tagged corner was streamed to the client
    let total = (SPIKE_EVENTS + TAIL_EVENTS) as u64;
    assert_eq!(summary.events_in, total, "no event may be dropped under overload");
    assert_eq!(got.corners, summary.corners_total);
    assert_eq!(stats.sessions_completed, 1);
    assert_eq!(stats.sessions_failed, 0);

    // the session visibly degraded: all three voltage steps down to the
    // 0.6 V floor, then the detector swap, then a full recovery
    assert_eq!(stats.sessions_degraded, 1);
    assert!(stats.degrade_vdd_steps >= 3, "vdd steps {}", stats.degrade_vdd_steps);
    assert!(stats.degrade_detector_swaps >= 1, "swaps {}", stats.degrade_detector_swaps);
    assert!(stats.degrade_recoveries >= 1, "recoveries {}", stats.degrade_recoveries);

    // the episode is visible on the wire: some v3 stats frame shows a
    // degraded level at a reduced voltage...
    assert_eq!(got.stats.len() as u64, total / 25_000);
    assert!(
        got.stats.iter().any(|s| s.degrade_level > 0 && s.vdd_mv < 1_200),
        "no stats frame showed the degraded state"
    );
    assert!(
        got.stats.iter().any(|s| s.vdd_mv == 600),
        "the shed ladder must reach the 0.6 V floor"
    );
    // ...and the calm tail ends back at the nominal operating point
    let last = got.stats.last().unwrap();
    assert_eq!(last.degrade_level, 0, "recovery must complete during the calm tail");
    assert_eq!(last.vdd_mv, 1_200, "voltage must return to nominal");
    assert_eq!(last.events_in, total);
}

#[test]
fn calm_streams_never_degrade() {
    // the same server config fed a stream whose event time tracks far
    // ahead of the wall clock must never shed anything
    let mut cfg = PipelineConfig::test64();
    cfg.backend = BackendKind::Nmc;
    cfg.detector = DetectorKind::Fast;
    cfg.record_per_event = false;
    let mut serve_cfg = ServeConfig::new(cfg);
    serve_cfg.degrade = Some(DegradeConfig::default());

    let server = StreamServer::new(serve_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let client = thread::spawn(move || {
        // a nominal-rate scenario stream: ~8k events spanning seconds of
        // event time, processed in milliseconds of wall time
        let grid = ScenarioGrid::smoke();
        let events = grid.enumerate()[0].build(9).generate(8_000);
        let conn = TcpStream::connect(addr).unwrap();
        let mut src = SliceSource::new(&events, 512);
        let mut sink = Collect::default();
        wire::feed_with_sink(conn, Hello::v3(2, Resolution::TEST64), &mut src, &mut sink).unwrap()
    });
    server.serve(&listener, Some(1)).unwrap();
    let summary = client.join().unwrap();
    let stats = server.shutdown();

    assert_eq!(summary.events_in, 8_000);
    assert_eq!(stats.sessions_completed, 1);
    assert_eq!(stats.sessions_degraded, 0);
    assert_eq!(stats.degrade_vdd_steps, 0);
    assert_eq!(stats.degrade_detector_swaps, 0);
    assert_eq!(stats.degrade_recoveries, 0);
}
