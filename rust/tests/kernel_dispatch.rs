//! Integration coverage for the SIMD kernel dispatch layer
//! (`nmc_tos::tos::kernel`): every path the host can run is swept
//! exhaustively against the scalar oracle, all four backends are checked
//! bit-exact under the startup-selected path, and the `NMC_TOS_KERNEL`
//! override contract is verified (CI runs this file once per forced path).

use nmc_tos::conventional::ConventionalTos;
use nmc_tos::events::{Event, Resolution};
use nmc_tos::nmc::{NmcConfig, NmcMacro};
use nmc_tos::tos::backend::{decrement_clamp_scalar, PatchRect};
use nmc_tos::tos::kernel::{active_path, available_paths, decrement_clamp_with, KernelPath};
use nmc_tos::tos::{ShardedTos, TosBackend, TosConfig, TosSurface};
use nmc_tos::util::rng::Rng;

/// Exhaustive alignment x width x threshold sweep, one dispatch path at a
/// time: every rect alignment and width inside row buffers from 1 to 67
/// pixels wide (crossing the 8/16/32-byte lane widths and their +-1
/// neighbours), at vertical positions covering the first, middle and last
/// rows (the last row exercises the backward-sliding end-of-slice
/// window), against the scalar oracle.
#[test]
fn exhaustive_alignment_width_threshold_sweep_per_path() {
    let thresholds = [0u8, 1, 127, 224, 225, 226, 255];
    let widths: Vec<usize> =
        (1..=18).chain([23, 24, 25, 31, 32, 33, 39, 40, 41, 63, 64, 67]).collect();
    for path in available_paths() {
        for &width in &widths {
            let data: Vec<u8> = (0..width * 3).map(|i| (i * 151 + 7) as u8).collect();
            for x0 in 0..width {
                for x1 in x0..width {
                    for (y0, y1) in [(0u16, 0u16), (1, 1), (2, 2), (0, 2)] {
                        let rect = PatchRect { x0: x0 as u16, x1: x1 as u16, y0, y1 };
                        for &th in &thresholds {
                            let mut got = data.clone();
                            let mut want = data.clone();
                            decrement_clamp_with(path, &mut got, width, 0, rect, th);
                            decrement_clamp_scalar(&mut want, width, 0, rect, th);
                            assert_eq!(
                                got, want,
                                "path {path} width {width} rect {rect:?} th {th}"
                            );
                        }
                    }
                }
            }
        }
    }
}

fn random_events(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|i| {
            Event::on(
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                i as u64,
            )
        })
        .collect()
}

/// All four backends stay bit-exact against the golden surface under the
/// dispatched kernel, and each reports the active path in its stats —
/// with the CI matrix forcing each `NMC_TOS_KERNEL` value in turn, this
/// covers every dispatch path on every backend.
#[test]
fn all_backends_bit_exact_and_report_active_path() {
    let res = Resolution::TEST64;
    let cfg = TosConfig::default();
    let mut events = random_events(res, 3_000, 0xD15);
    let t0 = events.len() as u64;
    events.push(Event::on(0, 0, t0 + 1));
    events.push(Event::on(res.width - 1, res.height - 1, t0 + 2));

    let mut golden = TosSurface::new(res, cfg).unwrap();
    golden.update_batch(&events);

    let backends: Vec<Box<dyn TosBackend>> = vec![
        Box::new(TosSurface::new(res, cfg).unwrap()),
        Box::new(ConventionalTos::new(res, cfg, 1.2).unwrap()),
        Box::new(NmcMacro::new(res, NmcConfig { tos: cfg, ..NmcConfig::default() }).unwrap()),
        Box::new(ShardedTos::new(res, cfg, 4).unwrap()),
    ];
    for mut b in backends {
        b.process_batch(&events);
        assert_eq!(b.tos_view(), golden.data(), "{} diverged", b.name());
        assert_eq!(b.stats().kernel, active_path(), "{} kernel report", b.name());
    }
}

/// The startup selection honours `NMC_TOS_KERNEL` when it names a path
/// this host can run, and otherwise picks a runnable path on its own.
/// (The selection is process-wide and latched, so this is the only test
/// binary assumption about the variable; the CI matrix re-runs the whole
/// file under each forced value.)
#[test]
fn selection_honours_env_override() {
    let selected = active_path();
    assert!(selected.runnable(), "selected path must be runnable");
    assert!(available_paths().contains(&selected));
    if let Ok(v) = std::env::var("NMC_TOS_KERNEL") {
        if let Some(forced) = KernelPath::parse(&v) {
            if forced.runnable() {
                assert_eq!(selected, forced, "override {v} not honoured");
            }
        }
    }
}

/// Sharded band slices never let the kernel touch rows outside the band:
/// run a stream whose patches all straddle band boundaries at every
/// runnable path's lane width and compare against golden.
#[test]
fn band_boundary_patches_exact_under_dispatch() {
    let res = Resolution::TEST64;
    let cfg = TosConfig::default();
    let mut events = Vec::new();
    for i in 0..400u64 {
        // hammer rows around the 2-row band boundaries from both sides
        events.push(Event::on((i % 64) as u16, (1 + (i % 4) * 2) as u16, i));
    }
    let mut golden = TosSurface::new(res, cfg).unwrap();
    golden.update_batch(&events);
    let mut sh = ShardedTos::new(res, cfg, 32).unwrap();
    sh.process_batch(&events);
    assert_eq!(golden.data(), sh.data());
}
