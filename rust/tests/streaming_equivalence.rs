//! Streamed ingestion must be indistinguishable from load-all ingestion:
//! for every backend x detector combination, a `run_stream` over a
//! file-backed [`EventSource`] with a chunk size far below the stream
//! length produces a `RunReport` bit-identical (surface, scores, corner
//! indices, telemetry counters) to `run` on the fully materialized
//! stream. Engine-less, so these run without `make artifacts`.

use nmc_tos::coordinator::sink::RecordingSink;
use nmc_tos::coordinator::{BackendKind, DetectorKind, Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::codec::{self, BinaryStreamSource};
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::Event;

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("nmc_tos_streaming_eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn file_streamed_run_bit_identical_for_every_combination() {
    let mut scene = SceneConfig::test64().build(123);
    let events = scene.generate(6_000);
    let path = scratch("all_combos.bin");
    codec::save(&path, &events).unwrap();

    for bk in BackendKind::ALL {
        for dk in DetectorKind::ALL {
            let mk_cfg = || {
                let mut cfg = PipelineConfig::test64();
                cfg.backend = bk;
                cfg.detector = dk;
                cfg.shards = 3;
                cfg
            };
            let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
            let want = pipe.run(&events).unwrap();

            // chunk size ≪ stream length, and not a divisor of it
            let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
            let mut src =
                BinaryStreamSource::new(std::fs::File::open(&path).unwrap(), 257).unwrap();
            let got = pipe.run_stream(&mut src).unwrap();

            assert_eq!(want.final_tos, got.final_tos, "{bk:?}/{dk:?} surface diverged");
            assert_eq!(want.scores, got.scores, "{bk:?}/{dk:?} scores diverged");
            assert_eq!(want.corners, got.corners, "{bk:?}/{dk:?} corners diverged");
            assert_eq!(want.events_in, got.events_in, "{bk:?}/{dk:?} events_in");
            assert_eq!(want.events_signal, got.events_signal, "{bk:?}/{dk:?} events_signal");
            assert_eq!(want.dvfs_switches, got.dvfs_switches, "{bk:?}/{dk:?} dvfs");
            assert_eq!(want.corners_total, got.corners_total, "{bk:?}/{dk:?} corner count");
        }
    }
}

#[test]
fn text_streamed_run_matches_binary_streamed_run() {
    // µs-integral timestamps survive the text format's 1e-6 rounding, so
    // both containers must drive the pipeline to the same result
    let mut scene = SceneConfig::test64().build(321);
    let events = scene.generate(4_000);

    let bin = scratch("text_vs_bin.bin");
    codec::save(&bin, &events).unwrap();
    let txt = scratch("text_vs_bin.txt");
    let mut buf = Vec::new();
    codec::write_text(&mut buf, &events).unwrap();
    std::fs::write(&txt, &buf).unwrap();

    let run_file = |path: &std::path::Path| {
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut src = nmc_tos::events::source::open(path, 509).unwrap();
        pipe.run_stream(&mut src).unwrap()
    };
    let from_bin = run_file(&bin);
    let from_txt = run_file(&txt);
    assert_eq!(from_bin.events_in, 4_000);
    assert_eq!(from_bin.final_tos, from_txt.final_tos);
    assert_eq!(from_bin.scores, from_txt.scores);
}

#[test]
fn file_streamed_sink_matches_load_all_report() {
    // a RecordingSink attached to a file-backed streamed run (recording
    // off — the sink is the only consumer) reproduces the load-all
    // report's per-event vectors exactly, at an awkward chunk size
    let mut scene = SceneConfig::test64().build(222);
    let events = scene.generate(7_000);
    let path = scratch("sink_eq.bin");
    codec::save(&path, &events).unwrap();

    let mut cfg = PipelineConfig::test64();
    cfg.detector = DetectorKind::Arc;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();

    cfg.record_per_event = false;
    let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
    let mut src = BinaryStreamSource::new(std::fs::File::open(&path).unwrap(), 313).unwrap();
    let mut sink = RecordingSink::default();
    let got = pipe.run_stream_with(&mut src, &mut sink).unwrap();

    assert!(got.signal_events.is_empty(), "recording off keeps the report lean");
    assert_eq!(got.corners_total, want.corners_total);
    assert_eq!(sink.signal_events, want.signal_events);
    assert_eq!(sink.scores, want.scores);
    assert_eq!(sink.corners, want.corners);
}

#[test]
fn scene_source_streams_through_pipeline() {
    // generator-backed source: same seed, same totals as the batch path
    let events = SceneConfig::test64().build(55).generate(8_000);
    let mut cfg = PipelineConfig::test64();
    cfg.detector = DetectorKind::EHarris;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();

    let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
    let mut src = SceneConfig::test64().build(55).into_source(8_000, 1_024);
    let got = pipe.run_stream(&mut src).unwrap();
    assert_eq!(want.final_tos, got.final_tos);
    assert_eq!(want.scores, got.scores);
    assert_eq!(want.corners, got.corners);
}

#[test]
fn chunk_boundaries_do_not_leak_into_batch_flush_state() {
    // a chunk size below BACKEND_BATCH_MAX must not change when the
    // sharded backend's pending buffer flushes
    let events: Vec<Event> = SceneConfig::test64().build(77).generate(10_000);
    let mut cfg = PipelineConfig::test64();
    cfg.backend = BackendKind::Sharded;
    cfg.detector = DetectorKind::Arc;
    cfg.shards = 4;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();
    for chunk in [64usize, 1000, 4096, 9_999] {
        let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
        let got = pipe.run_stream(&mut SliceSource::new(&events, chunk)).unwrap();
        assert_eq!(want.final_tos, got.final_tos, "chunk {chunk}");
        assert_eq!(want.scores, got.scores, "chunk {chunk}");
    }
}
