//! Streamed ingestion must be indistinguishable from load-all ingestion:
//! for every backend x detector combination, a `run_stream` over a
//! file-backed [`EventSource`] with a chunk size far below the stream
//! length produces a `RunReport` bit-identical (surface, scores, corner
//! indices, telemetry counters) to `run` on the fully materialized
//! stream. Engine-less, so these run without `make artifacts`.

use nmc_tos::coordinator::sink::RecordingSink;
use nmc_tos::coordinator::{BackendKind, DetectorKind, Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::events::codec::{self, BinaryStreamSource};
use nmc_tos::events::source::SliceSource;
use nmc_tos::events::Event;

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("nmc_tos_streaming_eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn file_streamed_run_bit_identical_for_every_combination() {
    let mut scene = SceneConfig::test64().build(123);
    let events = scene.generate(6_000);
    let path = scratch("all_combos.bin");
    codec::save(&path, &events).unwrap();

    for bk in BackendKind::ALL {
        for dk in DetectorKind::ALL {
            let mk_cfg = || {
                let mut cfg = PipelineConfig::test64();
                cfg.backend = bk;
                cfg.detector = dk;
                cfg.shards = 3;
                cfg
            };
            let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
            let want = pipe.run(&events).unwrap();

            // chunk size ≪ stream length, and not a divisor of it
            let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
            let mut src =
                BinaryStreamSource::new(std::fs::File::open(&path).unwrap(), 257).unwrap();
            let got = pipe.run_stream(&mut src).unwrap();

            assert_eq!(want.final_tos, got.final_tos, "{bk:?}/{dk:?} surface diverged");
            assert_eq!(want.scores, got.scores, "{bk:?}/{dk:?} scores diverged");
            assert_eq!(want.corners, got.corners, "{bk:?}/{dk:?} corners diverged");
            assert_eq!(want.events_in, got.events_in, "{bk:?}/{dk:?} events_in");
            assert_eq!(want.events_signal, got.events_signal, "{bk:?}/{dk:?} events_signal");
            assert_eq!(want.dvfs_switches, got.dvfs_switches, "{bk:?}/{dk:?} dvfs");
            assert_eq!(want.corners_total, got.corners_total, "{bk:?}/{dk:?} corner count");
        }
    }
}

#[test]
fn text_streamed_run_matches_binary_streamed_run() {
    // µs-integral timestamps survive the text format's 1e-6 rounding, so
    // both containers must drive the pipeline to the same result
    let mut scene = SceneConfig::test64().build(321);
    let events = scene.generate(4_000);

    let bin = scratch("text_vs_bin.bin");
    codec::save(&bin, &events).unwrap();
    let txt = scratch("text_vs_bin.txt");
    let mut buf = Vec::new();
    codec::write_text(&mut buf, &events).unwrap();
    std::fs::write(&txt, &buf).unwrap();

    let run_file = |path: &std::path::Path| {
        let mut cfg = PipelineConfig::test64();
        cfg.detector = DetectorKind::Fast;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let mut src = nmc_tos::events::source::open(path, 509).unwrap();
        pipe.run_stream(&mut src).unwrap()
    };
    let from_bin = run_file(&bin);
    let from_txt = run_file(&txt);
    assert_eq!(from_bin.events_in, 4_000);
    assert_eq!(from_bin.final_tos, from_txt.final_tos);
    assert_eq!(from_bin.scores, from_txt.scores);
}

#[test]
fn file_streamed_sink_matches_load_all_report() {
    // a RecordingSink attached to a file-backed streamed run (recording
    // off — the sink is the only consumer) reproduces the load-all
    // report's per-event vectors exactly, at an awkward chunk size
    let mut scene = SceneConfig::test64().build(222);
    let events = scene.generate(7_000);
    let path = scratch("sink_eq.bin");
    codec::save(&path, &events).unwrap();

    let mut cfg = PipelineConfig::test64();
    cfg.detector = DetectorKind::Arc;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();

    cfg.record_per_event = false;
    let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
    let mut src = BinaryStreamSource::new(std::fs::File::open(&path).unwrap(), 313).unwrap();
    let mut sink = RecordingSink::default();
    let got = pipe.run_stream_with(&mut src, &mut sink).unwrap();

    assert!(got.signal_events.is_empty(), "recording off keeps the report lean");
    assert_eq!(got.corners_total, want.corners_total);
    assert_eq!(sink.signal_events, want.signal_events);
    assert_eq!(sink.scores, want.scores);
    assert_eq!(sink.corners, want.corners);
}

#[test]
fn scene_source_streams_through_pipeline() {
    // generator-backed source: same seed, same totals as the batch path
    let events = SceneConfig::test64().build(55).generate(8_000);
    let mut cfg = PipelineConfig::test64();
    cfg.detector = DetectorKind::EHarris;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();

    let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
    let mut src = SceneConfig::test64().build(55).into_source(8_000, 1_024);
    let got = pipe.run_stream(&mut src).unwrap();
    assert_eq!(want.final_tos, got.final_tos);
    assert_eq!(want.scores, got.scores);
    assert_eq!(want.corners, got.corners);
}

#[test]
fn injected_faults_stream_bit_identically_at_any_chunk_size() {
    // the voltage-fault fast path is seeded and static per (seed, vdd,
    // cell): streamed ingestion at awkward chunk sizes must reproduce the
    // load-all run bit-for-bit — surface, scores, corners AND the fault
    // telemetry — at both published-nonzero BER voltages
    let events = SceneConfig::test64().build(88).generate(9_000);
    for vdd in [0.61, 0.60] {
        let mk_cfg = || {
            let mut cfg = PipelineConfig::test64();
            cfg.backend = BackendKind::Nmc;
            cfg.detector = DetectorKind::Fast;
            cfg.dvfs = None;
            cfg.fixed_vdd = vdd;
            cfg.inject_errors = true;
            cfg.seed = 0xFA_17;
            cfg
        };
        let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
        let want = pipe.run(&events).unwrap();
        let want_faults = want.backend.faults.expect("NMC run with injection reports faults");
        assert!(want_faults.flipped_bits > 0, "vdd {vdd}: faults must actually fire");

        for chunk in [97usize, 1_024, 8_999] {
            let mut pipe = Pipeline::from_config_without_engine(mk_cfg()).unwrap();
            let got = pipe.run_stream(&mut SliceSource::new(&events, chunk)).unwrap();
            assert_eq!(want.final_tos, got.final_tos, "vdd {vdd} chunk {chunk}: surface");
            assert_eq!(want.scores, got.scores, "vdd {vdd} chunk {chunk}: scores");
            assert_eq!(want.corners, got.corners, "vdd {vdd} chunk {chunk}: corners");
            let got_faults = got.backend.faults.unwrap();
            assert_eq!(want_faults, got_faults, "vdd {vdd} chunk {chunk}: fault telemetry");
        }
    }
}

#[test]
fn fault_sets_nest_monotonically_with_voltage() {
    // the fault map derives per (seed, cell, bit) with a threshold test
    // against p_bit(vdd), so the faulty-cell set at a higher voltage is a
    // subset of the set at any lower voltage — observable end-to-end as a
    // monotone faulty-cell count over the same event stream, collapsing
    // to exactly zero at the published-zero voltages
    let events = SceneConfig::test64().build(99).generate(8_000);
    let run_at = |vdd: f64| {
        let mut cfg = PipelineConfig::test64();
        cfg.backend = BackendKind::Nmc;
        cfg.detector = DetectorKind::Fast;
        cfg.dvfs = None;
        cfg.fixed_vdd = vdd;
        cfg.inject_errors = true;
        cfg.seed = 0xD1CE;
        let mut pipe = Pipeline::from_config_without_engine(cfg).unwrap();
        let report = pipe.run(&events).unwrap();
        report.backend.faults.expect("NMC run with injection reports faults")
    };
    let ladder: Vec<_> = [0.58, 0.60, 0.61, 0.62, 0.8, 1.2].iter().map(|&v| run_at(v)).collect();
    for w in ladder.windows(2) {
        assert!(
            w[0].faulty_cells >= w[1].faulty_cells,
            "fault sets must nest: {} cells @{} V vs {} cells @{} V",
            w[0].faulty_cells,
            w[0].vdd,
            w[1].faulty_cells,
            w[1].vdd
        );
        // same events => identical read traffic regardless of voltage
        assert_eq!(w[0].word_reads, w[1].word_reads);
    }
    assert!(ladder[0].faulty_cells > ladder[2].faulty_cells, "0.58 V strictly worse than 0.61 V");
    for f in &ladder[3..] {
        assert_eq!(f.faulty_cells, 0, "published-zero voltage {} V", f.vdd);
        assert_eq!(f.flipped_bits, 0);
    }
}

#[test]
fn chunk_boundaries_do_not_leak_into_batch_flush_state() {
    // a chunk size below BACKEND_BATCH_MAX must not change when the
    // sharded backend's pending buffer flushes
    let events: Vec<Event> = SceneConfig::test64().build(77).generate(10_000);
    let mut cfg = PipelineConfig::test64();
    cfg.backend = BackendKind::Sharded;
    cfg.detector = DetectorKind::Arc;
    cfg.shards = 4;
    let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
    let want = pipe.run(&events).unwrap();
    for chunk in [64usize, 1000, 4096, 9_999] {
        let mut pipe = Pipeline::from_config_without_engine(cfg.clone()).unwrap();
        let got = pipe.run_stream(&mut SliceSource::new(&events, chunk)).unwrap();
        assert_eq!(want.final_tos, got.final_tos, "chunk {chunk}");
        assert_eq!(want.scores, got.scores, "chunk {chunk}");
    }
}
