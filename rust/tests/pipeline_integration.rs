//! End-to-end integration tests of the full pipeline: scene -> STCF ->
//! NMC-TOS -> DVFS -> PJRT Harris -> corner tagging -> PR evaluation.
//!
//! These are the system-level claims of the paper reproduced at test
//! scale: corner detection works, BER at 0.6 V degrades AUC only mildly,
//! and the async (decoupled) LUT worker agrees with the sync path.

use nmc_tos::coordinator::{BackendKind, Pipeline, PipelineConfig};
use nmc_tos::datasets::synthetic::SceneConfig;
use nmc_tos::eval::PrCurve;
use nmc_tos::runtime::default_artifact_dir;

fn artifacts_available() -> bool {
    let ok = default_artifact_dir().join("meta.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn test_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::test64();
    cfg.dvfs = None; // deterministic voltage for AUC comparisons
    cfg.lut_refresh_events = 1_000;
    cfg
}

#[test]
fn detects_corners_better_than_chance() {
    if !artifacts_available() {
        return;
    }
    let mut scene = SceneConfig::test64().build(21);
    let (events, gt) = scene.generate_with_gt(60_000);
    let mut pipe = Pipeline::new(test_cfg()).unwrap();
    let report = pipe.run(&events).unwrap();
    assert!(report.lut_refreshes > 10);
    let scored = report.scored_events(&gt, 3.5);
    let base_rate =
        scored.iter().filter(|(_, l)| *l).count() as f64 / scored.len() as f64;
    let auc = PrCurve::from_scores(&scored, 101).auc();
    // the 64x64 test sensor has a high corner-event base rate (shapes
    // cover much of the frame), so require a solid absolute margin
    assert!(
        auc > base_rate + 0.12,
        "detector AUC {auc} not better than chance {base_rate}"
    );
    assert!(!report.corners.is_empty(), "no corners tagged");
}

#[test]
fn ber_degrades_auc_only_mildly() {
    if !artifacts_available() {
        return;
    }
    let mut scene = SceneConfig::test64().build(33);
    let (events, gt) = scene.generate_with_gt(60_000);

    let run = |vdd: f64, inject: bool| -> f64 {
        let mut cfg = test_cfg();
        cfg.fixed_vdd = vdd;
        cfg.inject_errors = inject;
        cfg.seed = 5;
        let mut pipe = Pipeline::new(cfg).unwrap();
        let report = pipe.run(&events).unwrap();
        PrCurve::from_scores(&report.scored_events(&gt, 3.5), 101).auc()
    };

    let clean = run(1.2, false);
    let ber_061 = run(0.61, true);
    let ber_060 = run(0.60, true);
    // paper Fig. 11: 0.2% BER ~unchanged; 2.5% BER costs ~0.03 AUC
    assert!((clean - ber_061).abs() < 0.05, "0.61 V moved AUC: {clean} -> {ber_061}");
    assert!(clean - ber_060 < 0.12, "0.6 V degraded too much: {clean} -> {ber_060}");
    assert!(ber_060 > 0.5 * clean, "0.6 V destroyed detection: {clean} -> {ber_060}");
}

#[test]
fn async_and_sync_modes_agree() {
    if !artifacts_available() {
        return;
    }
    let mut scene = SceneConfig::test64().build(44);
    let (events, gt) = scene.generate_with_gt(40_000);

    let mut sync_cfg = test_cfg();
    sync_cfg.async_refresh = false;
    let mut pipe = Pipeline::new(sync_cfg).unwrap();
    let sync_report = pipe.run(&events).unwrap();

    let mut async_cfg = test_cfg();
    async_cfg.async_refresh = true;
    let mut pipe = Pipeline::new(async_cfg).unwrap();
    let async_report = pipe.run(&events).unwrap();

    // identical event path: the worker NEVER back-pressures events, so the
    // TOS must be bit-identical regardless of scheduling
    assert_eq!(sync_report.events_signal, async_report.events_signal);
    assert_eq!(sync_report.final_tos, async_report.final_tos);
    assert!(async_report.lut_refreshes > 0, "worker never refreshed");

    // Scoring quality in async mode depends on host scheduling (on a
    // loaded single core the worker may lag the whole run — that IS the
    // luvHarris semantics), so the deterministic quality check is: both
    // runs' final surfaces produce the same LUT through the engine.
    let _ = &gt;
    let dir = default_artifact_dir();
    let manifest = nmc_tos::runtime::Manifest::load(&dir).unwrap();
    let mut engine = nmc_tos::runtime::HarrisEngine::load(&manifest, "test64").unwrap();
    let lut_a = engine.compute_u8(&sync_report.final_tos).unwrap();
    let lut_b = engine.compute_u8(&async_report.final_tos).unwrap();
    assert_eq!(lut_a, lut_b);
}

#[test]
fn dvfs_pipeline_runs_with_engine() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = PipelineConfig::test64();
    cfg.lut_refresh_events = 2_000;
    let mut scene = SceneConfig::test64().build(55);
    let events = scene.generate(40_000);
    let mut pipe = Pipeline::new(cfg).unwrap();
    let report = pipe.run(&events).unwrap();
    assert!(report.dvfs_switches >= 1, "DVFS never acted");
    assert!(report.lut_refreshes > 0);
}

#[test]
fn backend_swap_is_score_invariant_end_to_end() {
    // The whole point of the TosBackend refactor: with error injection off
    // and the voltage pinned, every backend produces the same surface, so
    // the same LUT, so identical per-event scores through the full engine.
    if !artifacts_available() {
        return;
    }
    let mut scene = SceneConfig::test64().build(77);
    let events = scene.generate(30_000);
    let mut reference: Option<(Vec<f64>, Vec<u8>)> = None;
    for bk in BackendKind::ALL {
        let mut cfg = test_cfg();
        cfg.backend = bk;
        cfg.shards = 4;
        let mut pipe = Pipeline::from_config(cfg).unwrap();
        let report = pipe.run(&events).unwrap();
        assert!(report.lut_refreshes > 0, "{bk:?}: LUT never refreshed");
        match &reference {
            None => reference = Some((report.scores, report.final_tos)),
            Some((scores, tos)) => {
                assert_eq!(tos, &report.final_tos, "{bk:?}: surface diverged");
                assert_eq!(scores, &report.scores, "{bk:?}: scores diverged");
            }
        }
    }
}

#[test]
fn resolution_mismatch_is_rejected() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = PipelineConfig::test64();
    cfg.artifact = "davis240".into(); // wrong artifact for 64x64 sensor
    assert!(Pipeline::new(cfg).is_err());
}

#[test]
fn deterministic_reports_per_seed() {
    if !artifacts_available() {
        return;
    }
    let run = || {
        let mut scene = SceneConfig::test64().build(66);
        let events = scene.generate(20_000);
        let mut pipe = Pipeline::new(test_cfg()).unwrap();
        pipe.run(&events).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.scores, b.scores);
    assert_eq!(a.corners, b.corners);
    assert_eq!(a.final_tos, b.final_tos);
}
